"""Batched sweep engine (`netsim.simulate_sweep`) — correctness invariants.

The contract: a sweep is *numerically identical* to running each grid point
through the per-config `simulate` path (which itself is a K=1 sweep), while
compiling exactly once per batch shape.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import netsim
from repro.netsim import engine
from repro.core import Algo, CCParams, MLTCPConfig, Variant

DT = 2e-5


def _proto(algo=Algo.RENO, variant=Variant.WI, **kw):
    return MLTCPConfig(cc=CCParams(algo=int(algo), variant=int(variant),
                                   tick_dt=DT, rtt=100e-6),
                       slope=1.75, intercept=0.25, **kw)


def _cfg(n_jobs=2, sim_time=0.6, seed=3, **kw):
    topo = netsim.dumbbell(n_jobs, sockets_per_job=2)
    jobs = netsim.JobSpec.simple([0.0075] * n_jobs, [25e6] * n_jobs)
    return netsim.SimConfig(topo=topo, jobs=jobs,
                            protocol=kw.pop("protocol", _proto()),
                            sim_time=sim_time, dt=DT, seed=seed, **kw)


def _tree_equal(a, b) -> bool:
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    return len(leaves_a) == len(leaves_b) and all(
        np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
        for x, y in zip(leaves_a, leaves_b))


def test_k1_sweep_matches_simulate_bitwise():
    cfg = _cfg()
    raw = netsim.simulate(cfg)
    sweep = netsim.make_sweep(cfg)
    assert netsim.sweep_len(sweep) == 1
    raw_k1 = jax.tree_util.tree_map(lambda x: x[0],
                                    netsim.simulate_sweep(cfg, sweep))
    assert _tree_equal(raw, raw_k1)


def test_slope_sweep_matches_sequential_runs():
    """A K=4 slope sweep == 4 sequential statically-reconfigured runs."""
    cfg = _cfg()
    slopes = [0.5, 1.0, 1.75, 2.5]
    sweep, points = netsim.grid_sweep(cfg, slope=slopes)
    assert [p["slope"] for p in points] == slopes
    results = netsim.postprocess_sweep(cfg, netsim.simulate_sweep(cfg, sweep))
    assert len(results) == 4
    for s, res in zip(slopes, results):
        cfg_s = dataclasses.replace(
            cfg, protocol=dataclasses.replace(cfg.protocol, slope=s))
        seq = netsim.postprocess(cfg_s, netsim.simulate(cfg_s))
        for j in range(2):
            assert res.iter_times[j].shape == seq.iter_times[j].shape
            np.testing.assert_allclose(res.iter_times[j], seq.iter_times[j],
                                       rtol=1e-4, atol=1e-6)
    # the sweep must actually change behaviour across the axis
    avgs = [r.avg_iter(0) for r in results]
    assert max(avgs) > min(avgs)


def test_seed_sweep_matches_sequential_runs():
    cfg = _cfg()
    seeds = [0, 7]
    results = netsim.postprocess_sweep(
        cfg, netsim.simulate_sweep(cfg, netsim.make_sweep(cfg, seed=seeds)))
    for seed, res in zip(seeds, results):
        seq = netsim.postprocess(
            cfg, netsim.simulate(dataclasses.replace(cfg, seed=seed)))
        np.testing.assert_allclose(np.concatenate(res.iter_times),
                                   np.concatenate(seq.iter_times),
                                   rtol=1e-4, atol=1e-6)


def test_sweep_compiles_once():
    """A K>=8 grid costs exactly one trace of the sweep program."""
    cfg = _cfg(sim_time=0.1)
    sweep, _ = netsim.grid_sweep(cfg, slope=[0.5, 1.0, 1.75, 2.5],
                                 intercept=[0.1, 0.5])
    assert netsim.sweep_len(sweep) == 8
    before = engine.TRACE_COUNT
    netsim.simulate_sweep(cfg, sweep)
    assert engine.TRACE_COUNT == before + 1
    # same static config + batch shape, new values: zero retraces
    sweep2, _ = netsim.grid_sweep(cfg, slope=[0.6, 1.1, 1.8, 2.6],
                                  intercept=[0.15, 0.55])
    netsim.simulate_sweep(cfg, sweep2)
    assert engine.TRACE_COUNT == before + 1


def test_sweep_output_shapes_survive_postprocess():
    cfg = _cfg(sim_time=0.3)
    k = 3
    raw = netsim.simulate_sweep(cfg, netsim.make_sweep(cfg, seed=[0, 1, 2]))
    assert raw.iter_times.shape[0] == k
    assert raw.trace_util.shape[0] == k
    results = netsim.postprocess_sweep(cfg, raw)
    assert len(results) == k
    for res in results:
        assert res.n_jobs == 2
        assert res.trace_util.ndim == 2            # [C, M], sweep axis gone
        assert res.trace_incomm.shape[1] == 2
        assert np.isfinite(res.avg_iter(0))


def test_red_threshold_sweep_changes_drop_rate():
    """RED thresholds ride the sweep axis: tighter thresholds, more drops."""
    cfg = _cfg(sim_time=0.5)
    results = netsim.postprocess_sweep(
        cfg, netsim.simulate_sweep(
            cfg, netsim.make_sweep(cfg, red_qmin=[20e3, 150e3],
                                   red_qmax=[200e3, 1.5e6])))
    assert results[0].drops_per_s > results[1].drops_per_s


def test_make_sweep_validates():
    cfg = _cfg(sim_time=0.1)
    with pytest.raises(ValueError, match="unknown sweep field"):
        netsim.make_sweep(cfg, bogus=[1.0, 2.0])
    with pytest.raises(ValueError, match="disagree"):
        netsim.make_sweep(cfg, slope=[1.0, 2.0], intercept=[0.1, 0.2, 0.3])
    with pytest.raises(ValueError, match="leading sweep axis"):
        netsim.simulate_sweep(cfg, netsim.sweep_of(cfg))  # unbatched


def test_kernel_sweep_runs_fused_and_matches_oracle_bitwise():
    """A K>1 sweep with use_pallas_kernel=True runs the fused kernel
    (FALLBACK_COUNT == 0) and is *bit-equal* to the jnp-oracle sweep on
    every RawSimOutput field — the operand-carried protocol scalars
    (DESIGN.md §4) leave no numerical daylight between the two paths."""
    from repro.kernels import ops

    cfg_o = _cfg(sim_time=0.4)
    cfg_k = dataclasses.replace(cfg_o, use_pallas_kernel=True)
    sweep, _ = netsim.grid_sweep(cfg_o, slope=[0.5, 1.75, 2.5])
    before_fb = ops.FALLBACK_COUNT
    before_tr = engine.TRACE_COUNT
    raw_k = netsim.simulate_sweep(cfg_k, sweep)
    assert ops.FALLBACK_COUNT == before_fb          # stayed fused
    assert engine.TRACE_COUNT == before_tr + 1      # one compile group
    raw_o = netsim.simulate_sweep(cfg_o, sweep)
    for name in raw_o._fields:
        assert _tree_equal(getattr(raw_o, name), getattr(raw_k, name)), \
            f"kernel sweep deviates from oracle on RawSimOutput.{name}"


def test_kernel_sweep_with_job_active_mask_matches_oracle():
    """The padded-jobs axis (job_active-masked lanes) under the fused
    kernel: still zero fallbacks, still bit-equal to the oracle sweep."""
    from repro.kernels import ops

    cfg_o = _cfg(n_jobs=3, sim_time=0.4)
    cfg_k = dataclasses.replace(cfg_o, use_pallas_kernel=True)
    mask = np.asarray([[1, 1, 1], [1, 1, 0], [1, 0, 0]], bool)
    sweep = netsim.make_sweep(cfg_o, seed=[0, 1, 2], job_active=mask)
    before = ops.FALLBACK_COUNT
    raw_k = netsim.simulate_sweep(cfg_k, sweep)
    assert ops.FALLBACK_COUNT == before
    raw_o = netsim.simulate_sweep(cfg_o, sweep)
    assert _tree_equal(raw_o, raw_k)
    # masked jobs really are inert under the kernel path
    counts = np.asarray(raw_k.iter_counts)
    assert counts[1, 2] == 0 and counts[2, 1] == 0 and counts[2, 2] == 0
    assert counts[0].min() > 0


def test_kernel_plan_reports_zero_fallbacks():
    """run_plan's compile-group accounting surfaces kernel fallbacks; a
    linear-F largest_data_sent plan must report none."""
    cfg = _cfg(sim_time=0.2)

    def build(pt):
        return dataclasses.replace(cfg, use_pallas_kernel=True)

    plan = netsim.Plan(name="kernel-smoke",
                       axes=(netsim.Axis("slope", (1.0, 1.75)),
                             netsim.Axis("seed", (0, 1))),
                       build=build)
    pr = netsim.run_plan(plan)
    assert pr.n_compile_groups == 1
    assert pr.n_kernel_fallbacks == 0
    assert len(pr) == 4


def test_static_factors_sweep():
    """The Static [67] baseline's per-job factors are sweepable.

    (Static needs a non-OFF variant so the factors reach the increase hook.)
    """
    cfg = _cfg(protocol=_proto(variant=Variant.WI), sim_time=0.5)
    factors = np.asarray([[1.5, 0.5], [1.0, 1.0]], np.float32)  # [K, J]
    results = netsim.postprocess_sweep(
        cfg, netsim.simulate_sweep(
            cfg, netsim.make_sweep(cfg, static_job_factors=factors)))
    # favored job 0 under skewed factors beats its even-factor self
    assert results[0].avg_iter(0) < results[1].avg_iter(0) * 1.05
