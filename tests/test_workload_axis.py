"""The workload sweep axis — phase programs and straggle probabilities as
traced SweepParams leaves.

The contract: workload *values* are operands, not compile-time constants —
zero-padded [J, P_max] phase programs run bit-identically to their unpadded
originals (the padding invariant compile-group merging relies on), traced
straggle probabilities reproduce the old static-JobSpec path exactly on
every base CC algorithm, and a workload-batched sweep keeps the fused
Pallas kernel engaged (no silent oracle fallback).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import netsim
from repro.core import Algo, CCParams, MLTCPConfig, Variant

DT = 2e-5


def _proto(algo=Algo.RENO, variant=Variant.WI, **kw):
    return MLTCPConfig(cc=CCParams(algo=int(algo), variant=int(variant),
                                   tick_dt=DT, rtt=100e-6),
                       slope=1.75, intercept=0.25, **kw)


def _cfg(n_jobs=2, sim_time=0.3, seed=3, straggle_prob=None, **kw):
    topo = netsim.dumbbell(n_jobs, sockets_per_job=2)
    jobs = netsim.JobSpec.simple([0.0075] * n_jobs, [25e6] * n_jobs,
                                 straggle_prob=straggle_prob)
    return netsim.SimConfig(topo=topo, jobs=jobs,
                            protocol=kw.pop("protocol", _proto()),
                            sim_time=sim_time, dt=DT, seed=seed, **kw)


def _tree_equal(a, b) -> bool:
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    return len(leaves_a) == len(leaves_b) and all(
        np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
        for x, y in zip(leaves_a, leaves_b))


def _pad_phase_columns(cfg, p_max: int):
    """cfg with its [J, P] phase programs zero-padded to [J, p_max]."""
    jobs = cfg.jobs
    j, p = jobs.compute.shape
    assert p_max >= p
    pad = ((0, 0), (0, p_max - p))
    return dataclasses.replace(cfg, jobs=dataclasses.replace(
        jobs,
        compute=np.pad(jobs.compute, pad),
        comm_bytes=np.pad(jobs.comm_bytes, pad)))


# ---------------------------------------------------------------------------
# The P_max padding invariant
# ---------------------------------------------------------------------------

def test_padded_phase_columns_bit_equal():
    """Zero phase columns beyond n_phases are inert: a [J, 3]-padded program
    is bit-identical to the [J, 1] original.  Compile-group merging pads
    members to a shared P_max, so this must hold exactly, not to tolerance.
    """
    cfg = _cfg()
    raw = netsim.simulate(cfg)
    raw_pad = netsim.simulate(_pad_phase_columns(cfg, 3))
    assert _tree_equal(raw, raw_pad)


def test_padded_columns_bit_equal_with_straggle_and_cassini():
    """The invariant holds with the straggler RNG and Cassini hold logic in
    the loop (both consume workload leaves)."""
    sched = netsim.CassiniSchedule(offset=np.asarray([0.0, 0.004]),
                                   period=np.asarray([0.012, 0.012]))
    cfg = _cfg(straggle_prob=[0.2, 0.2], cassini=sched)
    raw = netsim.simulate(cfg)
    raw_pad = netsim.simulate(_pad_phase_columns(cfg, 4))
    assert _tree_equal(raw, raw_pad)


# ---------------------------------------------------------------------------
# Traced workload values == old static-JobSpec path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", [Algo.RENO, Algo.CUBIC, Algo.DCQCN])
def test_traced_straggle_prob_matches_static_path(algo):
    """Overriding straggle_prob as a sweep leaf is bit-identical to baking
    the same probability into the JobSpec."""
    static = _cfg(straggle_prob=[0.2, 0.2], protocol=_proto(algo=algo))
    raw_static = netsim.simulate(static)
    clean = _cfg(protocol=_proto(algo=algo))
    sweep = netsim.make_sweep(clean, straggle_prob=0.2)   # scalar -> [J]
    raw_traced = jax.tree_util.tree_map(
        lambda x: x[0], netsim.simulate_sweep(clean, sweep))
    assert _tree_equal(raw_static, raw_traced)


def test_traced_phase_program_matches_static_path():
    """Overriding compute/comm_bytes as sweep leaves is bit-identical to a
    config built with those values (the compile-group merge contract)."""
    slow = _cfg()
    fast_jobs = netsim.JobSpec.simple([0.009, 0.009], [20e6, 20e6])
    fast = dataclasses.replace(slow, jobs=fast_jobs)
    raw_fast = netsim.simulate(fast)
    sweep = netsim.make_sweep(
        slow,
        compute=np.asarray(fast_jobs.compute, np.float32),
        comm_bytes=np.asarray(fast_jobs.comm_bytes, np.float32),
        iso_iter=np.asarray(fast_jobs.iso_iter_time, np.float32))
    raw_traced = jax.tree_util.tree_map(
        lambda x: x[0], netsim.simulate_sweep(slow, sweep))
    assert _tree_equal(raw_fast, raw_traced)


def test_grid_sweep_broadcasts_scalar_straggle_axis():
    """grid_sweep labels stay scalars while per-job fields broadcast to
    [K, J] values."""
    cfg = _cfg()
    sweep, points = netsim.grid_sweep(cfg, straggle_prob=[0.0, 0.1, 0.3])
    assert sweep.straggle_prob.shape == (3, 2)
    assert [p["straggle_prob"] for p in points] == [0.0, 0.1, 0.3]
    np.testing.assert_array_equal(
        np.asarray(sweep.straggle_prob),
        np.asarray([[0.0, 0.0], [0.1, 0.1], [0.3, 0.3]], np.float32))


# ---------------------------------------------------------------------------
# Error surface (satellite: clear non-leaf errors)
# ---------------------------------------------------------------------------

def test_make_sweep_rejects_non_leaf_fields():
    cfg = _cfg()
    with pytest.raises(ValueError, match="unknown sweep field"):
        netsim.make_sweep(cfg, n_phases=[1, 2])
    with pytest.raises(ValueError, match="valid leaves.*straggle_prob"):
        netsim.make_sweep(cfg, straggle=[0.1])
    with pytest.raises(ValueError, match="valid leaves"):
        netsim.grid_sweep(cfg, start_offset=[0.0, 0.1])
    with pytest.raises(ValueError, match="expected a scalar"):
        netsim.make_sweep(cfg, straggle_prob=np.zeros((2, 2, 2)))


# ---------------------------------------------------------------------------
# Kernel fuzz: workload-batched sweeps stay on the fused kernel
# ---------------------------------------------------------------------------

def test_workload_batched_kernel_sweep_stays_fused():
    """A sweep batching compute scale, comm bytes, and straggle probability
    runs the fused kernel without a single oracle fallback and bit-matches
    the pure-jnp oracle."""
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    cfg_o = _cfg(sim_time=0.2)
    j, p = cfg_o.jobs.compute.shape
    k = 4
    compute = (np.asarray(cfg_o.jobs.compute, np.float32)[None] *
               rng.uniform(0.5, 1.5, (k, 1, 1)).astype(np.float32))
    comm = (np.asarray(cfg_o.jobs.comm_bytes, np.float32)[None] *
            rng.uniform(0.8, 1.2, (k, 1, 1)).astype(np.float32))
    probs = rng.uniform(0.0, 0.3, (k, j)).astype(np.float32)
    over = dict(compute=compute, comm_bytes=comm, straggle_prob=probs)

    raw_o = netsim.simulate_sweep(cfg_o, netsim.make_sweep(cfg_o, **over))
    cfg_k = dataclasses.replace(cfg_o, use_pallas_kernel=True)
    before = ops.FALLBACK_COUNT
    raw_k = netsim.simulate_sweep(cfg_k, netsim.make_sweep(cfg_k, **over))
    assert ops.FALLBACK_COUNT == before          # stayed fused
    assert _tree_equal(raw_o, raw_k)
