"""Per-architecture smoke tests: reduced same-family configs, one forward and
one decode step on CPU, asserting output shapes and finiteness (per brief)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import api, transformer

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, t=16):
    batch = {"tokens": jax.random.randint(KEY, (b, t), 0, cfg.vocab)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            KEY, (b, max(t // cfg.enc_seq_divisor, 4), cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            KEY, (b, cfg.vision_tokens, cfg.vit_dim))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_config(arch).scaled_down()
    params = api.init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = jax.jit(lambda p, b: api.forward(cfg, p, b))(params, batch)
    t = batch["tokens"].shape[1] + (cfg.vision_tokens if cfg.family == "vlm"
                                    else 0)
    assert logits.shape == (2, t, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_smoke(arch):
    cfg = get_config(arch).scaled_down()
    params = api.init_params(cfg, KEY)
    b, s = 2, 32
    cache = api.init_cache(cfg, b, s)
    if cfg.family == "audio":
        from repro.models import encdec
        mem = encdec.encode(cfg, params,
                            jax.random.normal(KEY, (b, 8, cfg.d_model)))
        cache = encdec.prefill_cross(cfg, params, mem, cache)
    tok = jnp.array([1, 2], jnp.int32)
    step = jax.jit(lambda p, c, t, i: api.decode_step(cfg, p, c, t, i))
    logits, cache = step(params, cache, tok, jnp.asarray(0, jnp.int32))
    logits, cache = step(params, cache, tok, jnp.asarray(1, jnp.int32))
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Teacher-forced forward and step-by-step decode agree on logits."""
    cfg = get_config(arch).scaled_down(capacity_factor=16.0)
    if cfg.family in ("audio", "vlm"):
        pytest.skip("frontend stubs make position bookkeeping differ")
    params = api.init_params(cfg, KEY)
    b, t = 1, 8
    toks = jax.random.randint(KEY, (b, t), 0, cfg.vocab)
    full, _ = api.forward(cfg, params, {"tokens": toks}, remat=False)
    cache = api.init_cache(cfg, b, t)
    outs = []
    for i in range(t):
        lg, cache = api.decode_step(cfg, params, cache, toks[:, i],
                                    jnp.asarray(i, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    assert jnp.allclose(full, dec, atol=2e-3, rtol=2e-3), \
        float(jnp.max(jnp.abs(full - dec)))


def test_param_counts_match_public_sizes():
    expect = {
        "deepseek-moe-16b": 16.4e9,
        "llama4-maverick-400b-a17b": 400e9,
        "gemma2-27b": 27.2e9,
        "olmo-1b": 1.18e9,
        "qwen3-1.7b": 1.7e9,
        "qwen1.5-4b": 3.95e9,
        "recurrentgemma-2b": 2.9e9,
        "xlstm-125m": 0.15e9,
    }
    for arch, n_expect in expect.items():
        n = transformer.param_count(get_config(arch))
        assert abs(n - n_expect) / n_expect < 0.12, (arch, n, n_expect)
