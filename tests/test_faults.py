"""Traced fault injection (netsim.faults) + fault-tolerant run_plan.

Pins the three contracts DESIGN.md §8 promises:

* faults off is *free*: ``faults=None`` and an armed-but-identity schedule
  produce bit-identical trajectories, on the fused kernel path, with zero
  fallbacks — and schedule values are data, so new schedules never retrace;
* the fault channels do what they claim at the engine/link level (churn
  freezes a job, blackholes stall the holed job, flaps stretch iterations,
  straggle bursts straggle);
* a poisoned compile group under ``run_plan(keep_going=True)`` is salvaged
  (healthy groups complete + cache, the failure is reported on
  ``group_errors``), and a corrupt cache entry is quarantined, never fatal.
"""
import dataclasses
import os
import warnings

import jax
import numpy as np
import pytest

from repro import netsim
from repro.netsim import engine
from repro.core import Algo, CCParams, MLTCPConfig, Variant

DT = 2e-5

ALGOS = {"reno": Algo.RENO, "cubic": Algo.CUBIC, "dcqcn": Algo.DCQCN}


def _proto(algo=Algo.RENO, variant=Variant.WI, **kw):
    return MLTCPConfig(cc=CCParams(algo=int(algo), variant=int(variant),
                                   tick_dt=kw.pop("tick_dt", DT),
                                   rtt=100e-6),
                       slope=1.75, intercept=0.25, **kw)


def _cfg(n_jobs=2, sim_time=0.5, seed=3, **kw):
    topo = netsim.dumbbell(n_jobs, sockets_per_job=2)
    jobs = netsim.JobSpec.simple([0.0075] * n_jobs, [25e6] * n_jobs)
    return netsim.SimConfig(topo=topo, jobs=jobs,
                            protocol=kw.pop("protocol", _proto()),
                            sim_time=sim_time, dt=DT, seed=seed, **kw)


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
        for x, y in zip(la, lb))


ALL_SPEC = netsim.FaultSpec(n_events=4, churn=True, link_flaps=True,
                            blackholes=True, straggle_bursts=True)


# ---------------------------------------------------------------------------
# Faults off is free
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["reno", "cubic", "dcqcn"])
def test_armed_identity_schedule_is_bitwise_noop(algo):
    """For every CC algorithm, arming a FaultSpec with the identity
    schedule (the default when no overrides arrive) runs bit-identical to
    ``faults=None`` on the fused kernel path, with zero oracle fallbacks —
    every channel's no-op really is exact (`& True`, `* 1.0`, `+ 0.0`,
    `where(False)`)."""
    from repro.kernels import ops

    proto = _proto(algo=ALGOS[algo])
    cfg = _cfg(sim_time=0.25, protocol=proto, use_pallas_kernel=True)
    before = ops.FALLBACK_COUNT
    raw_off = netsim.simulate(cfg)
    raw_armed = netsim.simulate(dataclasses.replace(cfg, faults=ALL_SPEC))
    assert ops.FALLBACK_COUNT == before, \
        f"{algo}: fault channels knocked the CC-tick kernel off the fused path"
    for name in raw_off._fields:
        assert _tree_equal(getattr(raw_off, name), getattr(raw_armed, name)), \
            f"{algo}: identity fault schedule changed RawSimOutput.{name}"


def test_explicit_identity_schedule_matches_default():
    """`identity_schedule` fed through make_sweep == the armed default."""
    cfg = _cfg(sim_time=0.2, faults=ALL_SPEC)
    ident = netsim.identity_schedule(cfg, ALL_SPEC)
    raw_default = netsim.simulate(cfg)
    raw_explicit = jax.tree_util.tree_map(
        lambda x: x[0],
        netsim.simulate_sweep(cfg, netsim.make_sweep(cfg, **ident.overrides())))
    assert _tree_equal(raw_default, raw_explicit)


def test_fault_schedules_are_data_not_structure():
    """Two different non-trivial schedules under one FaultSpec share one
    trace: the schedule rides in SweepParams, so re-running with new fault
    values costs zero retraces (the batched-churn-grid property the churn
    benchmark relies on)."""
    spec = netsim.FaultSpec(n_events=4, churn=True, link_flaps=True)
    cfg = _cfg(sim_time=0.2, faults=spec)
    sched_a = netsim.fault_schedule(
        cfg, [netsim.job_departs(0.05, 1), netsim.job_arrives(0.1, 1)],
        spec=spec)
    sched_b = netsim.fault_schedule(
        cfg, [netsim.link_flap(0.04, 0.12, 0, 0.5)], spec=spec)
    before = engine.TRACE_COUNT
    netsim.simulate_sweep(cfg, netsim.make_sweep(cfg, **sched_a.overrides()))
    assert engine.TRACE_COUNT == before + 1
    netsim.simulate_sweep(cfg, netsim.make_sweep(cfg, **sched_b.overrides()))
    assert engine.TRACE_COUNT == before + 1, \
        "a new fault schedule under the same spec retraced the program"


# ---------------------------------------------------------------------------
# Schedule builder semantics
# ---------------------------------------------------------------------------

def test_schedule_builds_sorted_padded_event_table():
    spec = netsim.FaultSpec(n_events=6, churn=True, link_flaps=True)
    cfg = _cfg(sim_time=0.5, faults=spec)
    sched = netsim.fault_schedule(
        cfg, [netsim.link_flap(0.2, 0.3, 0, 0.5),
              netsim.job_departs(0.1, 1)], spec=spec)
    ticks = sched.values["fault_tick"]
    assert ticks.shape == (6,)
    # boundaries: 0, departure, flap start, flap end — then padding rows
    # that duplicate the last boundary (rank-sum row selection picks the
    # LAST duplicate, so padding shadows nothing)
    expect = [0, round(0.1 / DT), round(0.2 / DT), round(0.3 / DT)]
    assert list(ticks[:4]) == expect
    assert list(ticks[4:]) == [expect[-1]] * 2
    # padding rows carry the final row's channel state verbatim
    assert np.array_equal(sched.values["fault_job_active"][4],
                          sched.values["fault_job_active"][3])
    assert np.array_equal(sched.values["fault_link_scale"][4],
                          sched.values["fault_link_scale"][3])


def test_schedule_churn_forward_fills_and_windows_apply():
    spec = netsim.FaultSpec(n_events=5, churn=True, link_flaps=True)
    cfg = _cfg(sim_time=0.5, faults=spec)
    sched = netsim.fault_schedule(
        cfg, [netsim.job_departs(0.1, 1), netsim.job_arrives(0.3, 1),
              netsim.link_flap(0.1, 0.3, 0, 0.25)], spec=spec)
    active = sched.values["fault_job_active"]
    # rows: t=0 (all in), depart (job 1 out ... persists), arrive (back)
    assert active[:, 0].all()
    assert list(active[:3, 1]) == [True, False, True]
    scale = sched.values["fault_link_scale"][:3, 0]
    np.testing.assert_allclose(scale, [1.0, 0.25, 1.0])


def test_schedule_overlapping_flaps_compose_multiplicatively():
    cfg = _cfg(sim_time=0.5)
    sched = netsim.fault_schedule(
        cfg, [netsim.link_flap(0.1, 0.4, 0, 0.5),
              netsim.link_flap(0.2, 0.3, 0, 0.5)])
    scale = sched.values["fault_link_scale"][:, 0]
    # rows at 0, .1, .2, .3, .4: nested flap windows multiply
    np.testing.assert_allclose(scale, [1.0, 0.5, 0.25, 0.5, 1.0])


def test_schedule_validates():
    cfg = _cfg()  # 2 jobs, 4 flows, 1 bottleneck + leaf links
    with pytest.raises(ValueError, match="indexes"):
        netsim.fault_schedule(cfg, [netsim.job_departs(0.1, 7)])
    with pytest.raises(ValueError, match="does not arm"):
        netsim.fault_schedule(
            cfg, [netsim.job_departs(0.1, 1)],
            spec=netsim.FaultSpec(n_events=4, link_flaps=True))
    with pytest.raises(ValueError, match="event rows"):
        netsim.fault_schedule(
            cfg, [netsim.link_flap(0.1, 0.2, 0, 0.5),
                  netsim.link_flap(0.3, 0.4, 0, 0.5)],
            spec=netsim.FaultSpec(n_events=2, link_flaps=True))
    with pytest.raises(ValueError, match="empty"):
        netsim.link_flap(0.2, 0.2, 0, 0.5)
    with pytest.raises(ValueError, match="at least one flow"):
        netsim.blackhole(0.1, 0.2, [])
    with pytest.raises(ValueError, match="channel"):
        netsim.faults.FaultEvent("gremlin", 0.1, None, (), 1.0)


# ---------------------------------------------------------------------------
# Fault dynamics
# ---------------------------------------------------------------------------

def _iter_counts(cfg, overrides=None):
    sweep = (netsim.make_sweep(cfg, **overrides) if overrides
             else netsim.make_sweep(cfg))
    raw = netsim.simulate_sweep(cfg, sweep)
    return np.asarray(raw.iter_counts)[0]


def test_churn_freezes_and_resumes_a_job():
    spec = netsim.FaultSpec(n_events=3, churn=True)
    cfg = _cfg(sim_time=0.6, faults=spec)
    base = _iter_counts(cfg)
    gone = netsim.fault_schedule(      # job 1 out for the middle third
        cfg, [netsim.job_departs(0.2, 1), netsim.job_arrives(0.4, 1)],
        spec=spec)
    faulted = _iter_counts(cfg, gone.overrides())
    # the churned job lost roughly its absence window of progress...
    assert faulted[1] < base[1] * 0.85
    # ...but kept running outside it; the survivor never slowed down
    assert faulted[1] > 0
    assert faulted[0] >= base[0]


def test_blackhole_stalls_only_the_holed_job():
    spec = netsim.FaultSpec(n_events=3, blackholes=True)
    cfg = _cfg(sim_time=0.6, faults=spec)
    base = _iter_counts(cfg)
    flows = [int(f) for f in
             np.nonzero(np.asarray(cfg.topo.flow_to_job) == 1)[0]]
    holed = netsim.fault_schedule(
        cfg, [netsim.blackhole(0.2, 0.4, flows)], spec=spec)
    faulted = _iter_counts(cfg, holed.overrides())
    assert faulted[1] < base[1] * 0.85   # null-routed: no delivery, no progress
    assert faulted[0] >= base[0] * 0.9   # the other job rides through


def test_link_flap_stretches_iterations():
    spec = netsim.FaultSpec(n_events=3, link_flaps=True)
    cfg = _cfg(sim_time=0.6, faults=spec)
    base = _iter_counts(cfg)
    flapped = netsim.fault_schedule(    # bottleneck at quarter capacity
        cfg, [netsim.link_flap(0.2, 0.5, 0, 0.25)], spec=spec)
    faulted = _iter_counts(cfg, flapped.overrides())
    assert faulted.sum() < base.sum() * 0.9


def test_straggle_burst_slows_progress():
    """An uncontended job under a prob-1.0 straggle burst loses the
    straggle surcharge (5-10% of its isolated iteration time, sampled per
    iteration) on every iteration of the window — measurable directly as
    lost iterations, with no contention noise in the way."""
    spec = netsim.FaultSpec(n_events=3, straggle_bursts=True)
    cfg = _cfg(n_jobs=1, sim_time=0.8, faults=spec)
    base = _iter_counts(cfg)
    bursty = netsim.fault_schedule(
        cfg, [netsim.straggle_burst(0.0, None, 1.0)], spec=spec)
    faulted = _iter_counts(cfg, bursty.overrides())
    assert faulted.sum() < base.sum() - 1


def test_reinterleave_detector_reports_every_event_window():
    """The per-event verdict machinery: one report per schedule row, with
    start ticks matching the table and finite re-interleave iters only
    where re-convergence happened."""
    spec = netsim.FaultSpec(n_events=3, churn=True)
    sched_events = [netsim.job_departs(0.25, 1), netsim.job_arrives(0.45, 1)]
    tel = netsim.TelemetrySpec(
        probes=("interleave_overlap", "job_iter"),
        detectors=("interleave", "iter_sketch", "reinterleave"),
        stride=8)

    def build(pt):
        return _cfg(sim_time=0.8, faults=spec, telemetry=tel)

    plan = netsim.Plan(
        name="reinterleave-smoke",
        axes=(netsim.Axis(
            "schedule", ("gauntlet",), field="*",
            resolve=lambda label: (lambda cfg: netsim.fault_schedule(
                cfg, sched_events, spec=spec).overrides())),),
        build=build)
    res = netsim.run_plan(plan).results[0]
    reports = res.telemetry.fault_events
    assert len(reports) == spec.n_events
    cfg = build(None)
    table = netsim.fault_schedule(cfg, sched_events, spec=spec)
    assert [r.start_tick for r in reports] == \
        list(table.values["fault_tick"])
    for r in reports:
        assert r.reconverged in (True, False)
        if r.reconverged:
            assert np.isfinite(r.reinterleave_iters)


# ---------------------------------------------------------------------------
# Fault-tolerant run_plan + cache quarantine
# ---------------------------------------------------------------------------

def _poisonable_plan(sim_time=0.15):
    def build(pt):
        # the poisoned point builds a config whose protocol tick grid
        # disagrees with the simulator's — simulate_sweep rejects it at
        # group-run time, inside run_plan's per-group isolation
        tick = DT * 2 if pt["cell"] == "poison" else DT
        return _cfg(sim_time=sim_time, protocol=_proto(tick_dt=tick))
    return netsim.Plan(name="salvage",
                       axes=(netsim.Axis("cell", ("ok-a", "poison", "ok-b")),
                             netsim.Axis("seed", (0, 1))),
                       build=build)


def test_keep_going_false_reraises():
    with pytest.raises(ValueError, match="tick_dt"):
        netsim.run_plan(_poisonable_plan())


def test_keep_going_salvages_healthy_groups(tmp_path):
    cache = str(tmp_path / "cache")
    pr = netsim.run_plan(_poisonable_plan(), keep_going=True,
                         cache_dir=cache)
    # the poisoned group is reported, not raised...
    assert len(pr.group_errors) == 1
    err = pr.group_errors[0]
    assert "ValueError" in err.error and "tick_dt" in err.error
    assert all("cell=poison" in lbl for lbl in err.point_labels)
    assert "algo=" in err.signature
    # ...its members' slots stay None, every healthy cell completed
    missing = [r for r in pr.results if r is None]
    assert len(missing) == 2
    assert len(pr.select(cell="ok-a")) == 2
    assert len(pr.select(cell="ok-b")) == 2
    with pytest.raises(KeyError):
        pr.select(cell="poison")
    # healthy cells were cached: a re-run simulates nothing new
    pr2 = netsim.run_plan(_poisonable_plan(), keep_going=True,
                          cache_dir=cache)
    assert pr2.n_cache_hits == 4
    assert len(pr2.group_errors) == 1


def test_corrupt_cache_entry_quarantined_and_recomputed(tmp_path):
    cache = str(tmp_path / "cache")
    cfg = _cfg(sim_time=0.15)
    plan = netsim.Plan(name="cache-roundtrip",
                       axes=(netsim.Axis("seed", (0, 1, 2)),),
                       build=lambda pt: cfg)
    netsim.run_plan(plan, cache_dir=cache)
    entries = sorted(f for f in os.listdir(cache) if f.endswith(".pkl"))
    assert len(entries) == 3
    # truncate one entry mid-pickle, zero out another
    with open(os.path.join(cache, entries[0]), "wb") as f:
        f.write(b"\x80\x04corrupt")
    with open(os.path.join(cache, entries[1]), "wb"):
        pass
    with pytest.warns(RuntimeWarning, match="quarantined"):
        pr = netsim.run_plan(plan, cache_dir=cache)
    # the two damaged points were re-simulated, the healthy one served
    assert pr.n_cache_hits == 1
    assert all(r is not None for r in pr.results)
    quarantined = [f for f in os.listdir(cache) if f.endswith(".corrupt")]
    assert len(quarantined) >= 1
    # the re-run rewrote healthy entries: a third run is all hits, silently
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        pr3 = netsim.run_plan(plan, cache_dir=cache)
    assert pr3.n_cache_hits == 3


def test_prune_cache_evicts_quarantine_and_debris(tmp_path):
    cache = tmp_path / "cache"
    cache.mkdir()
    keep = cache / "v2-deadbeef.pkl"
    keep.write_bytes(b"x" * 16)
    debris = [cache / "v1-old.pkl",          # stale schema
              cache / "v2-torn.pkl.tmp",     # torn write
              cache / "v2-bad.pkl.corrupt",  # quarantined
              cache / "v2-empty.pkl"]        # zero-byte
    for p in debris[:-1]:
        p.write_bytes(b"x")
    debris[-1].write_bytes(b"")
    assert netsim.prune_cache(str(cache)) == len(debris)
    assert sorted(os.listdir(cache)) == [keep.name]
    assert netsim.prune_cache(str(tmp_path / "nonexistent")) == 0
