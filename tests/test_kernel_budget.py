"""Layers 4+5: kernel-body lint fixtures (every rule fires on a broken
kernel, the real kernel lints clean) and HLO budget bookkeeping (drift /
missing / stale / env-mismatch / unknown-dtype), plus the roofline dtype
regression the budget layer depends on."""
from __future__ import annotations

import json
import warnings

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import netsim
from repro.analysis import find_kernel_eqns, lint_kernel, lint_kernel_eqn
from repro.analysis.hlo_budget import (BudgetBook, METRICS, SCHEMA,
                                       env_fingerprint)
from repro.core import Algo, CCParams, MLTCPConfig, Variant
from repro.kernels import mltcp_step as ms
from repro.kernels import ops
from repro.netsim import engine
from repro.roofline import hlo

DT = 2e-5
ROWS, NDYN = 8, 5


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# Kernel-lint fixtures: a mini CC-tick-shaped pallas_call per violation.
# The kernel fn is named `_kernel` so find_kernel_eqns prefix-matches it
# exactly as it matches the real kernel (and its vmapped `_kernel_batched`).
# ---------------------------------------------------------------------------

_LAYOUT = ms.KernelLayout(rows=ROWS, block=(ROWS, ms.LANES), grid=(1,),
                          n_inputs=3, n_outputs=1, dyn_index=0,
                          dyn_shape=(NDYN,), use_static_factors=False)

_STATE = pl.BlockSpec((ROWS, ms.LANES), lambda i: (i, 0))
_SMEM = pl.BlockSpec(memory_space=pltpu.SMEM)


def _kernel(dyn_ref, a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] * dyn_ref[0] + b_ref[...]


def _args():
    return (jnp.zeros((NDYN,), jnp.float32),
            jnp.zeros((ROWS, ms.LANES), jnp.float32),
            jnp.zeros((ROWS, ms.LANES), jnp.float32))


def _call(kernel=_kernel, in_specs=(_SMEM, _STATE, _STATE),
          out_specs=_STATE, grid=(1,)):
    def run(dyn, a, b):
        return pl.pallas_call(
            kernel, grid=grid, in_specs=list(in_specs),
            out_specs=out_specs,
            out_shape=jax.ShapeDtypeStruct((ROWS, ms.LANES), jnp.float32),
            interpret=True)(dyn, a, b)
    return run


def _lint(run, layout=_LAYOUT, **kw):
    jaxpr = jax.make_jaxpr(run)(*_args())
    eqns = find_kernel_eqns(jaxpr)
    assert len(eqns) == 1
    return lint_kernel_eqn(eqns[0], layout, label="fix", **kw)


def test_fixture_kernel_is_clean():
    findings, facts = _lint(_call())
    assert findings == []
    assert facts["vmem_bytes_per_step"] == 2 * 3 * ROWS * ms.LANES * 4
    assert facts["body_eqns"] > 0


def test_dyn_not_smem_fires():
    # dyn rides as a full-array VMEM block instead of SMEM scalars
    dyn_vmem = pl.BlockSpec((NDYN,), lambda i: (0,))
    findings, _ = _lint(_call(in_specs=(dyn_vmem, _STATE, _STATE)))
    assert "kernel/dyn-not-smem" in _rules(findings)


def test_state_not_vmem_fires():
    # a flow-state ref pinned to SMEM serializes the vector loads
    def _kernel(dyn_ref, a_ref, b_ref, o_ref):
        o_ref[...] = b_ref[...] * dyn_ref[0] + a_ref[0, 0]

    findings, _ = _lint(_call(kernel=_kernel, in_specs=(_SMEM, _SMEM, _STATE)))
    assert "kernel/state-not-vmem" in _rules(findings)


def test_block_misaligned_and_grid_remainder_fire():
    half = pl.BlockSpec((ROWS // 2, ms.LANES), lambda i: (i, 0))
    findings, _ = _lint(_call(in_specs=(_SMEM, half, half),
                              out_specs=half, grid=(2,)))
    got = _rules(findings)
    assert "kernel/block-misaligned" in got
    assert "kernel/grid-remainder" in got


def test_operand_mismatch_fires():
    wrong = ms.KernelLayout(rows=ROWS, block=(ROWS, ms.LANES), grid=(1,),
                            n_inputs=4, n_outputs=2, dyn_index=0,
                            dyn_shape=(NDYN,), use_static_factors=True)
    findings, _ = _lint(_call(), layout=wrong)
    assert "kernel/operand-mismatch" in _rules(findings)


def test_f64_in_body_fires():
    def _kernel(dyn_ref, a_ref, b_ref, o_ref):
        v = a_ref[...].astype(jnp.float64) * 2.0
        o_ref[...] = v.astype(jnp.float32) + b_ref[...]

    with jax.experimental.enable_x64():
        findings, _ = _lint(_call(kernel=_kernel))
    assert "kernel/f64-in-body" in _rules(findings)


def test_gather_scatter_fires():
    def _kernel(dyn_ref, a_ref, b_ref, o_ref):
        idx = dyn_ref[0].astype(jnp.int32) % (ROWS * ms.LANES)
        o_ref[...] = b_ref[...] + jnp.take(a_ref[...].ravel(), idx,
                                           mode="clip")

    findings, _ = _lint(_call(kernel=_kernel))
    assert "kernel/gather-scatter" in _rules(findings)


def test_nested_control_fires():
    def _kernel(dyn_ref, a_ref, b_ref, o_ref):
        o_ref[...] = jax.lax.cond(dyn_ref[0] > 0.0,
                                  lambda: a_ref[...] + b_ref[...],
                                  lambda: a_ref[...] - b_ref[...])

    findings, _ = _lint(_call(kernel=_kernel))
    assert "kernel/nested-control" in _rules(findings)


def test_dyn_written_fires():
    def _kernel(dyn_ref, a_ref, b_ref, o_ref):
        dyn_ref[0] = jnp.float32(1.0)
        o_ref[...] = a_ref[...] + b_ref[...]

    findings, _ = _lint(_call(kernel=_kernel))
    assert "kernel/dyn-written" in _rules(findings)


def test_vmem_budget_fires():
    findings, _ = _lint(_call(), vmem_ceiling_bytes=1024)
    assert "kernel/vmem-budget" in _rules(findings)


def test_grid_remainder_fires_on_uncoverable_rows():
    # an expectation whose rows are not block-divisible can never be
    # covered exactly — the rule fires on the layout itself
    ragged = ms.KernelLayout(rows=12, block=(8, ms.LANES), grid=(1,),
                             n_inputs=3, n_outputs=1, dyn_index=0,
                             dyn_shape=(NDYN,), use_static_factors=False)
    findings, _ = _lint(_call(), layout=ragged)
    assert "kernel/grid-remainder" in _rules(findings)


# ---------------------------------------------------------------------------
# The real kernel lints clean — per specialization, through the real
# trace path, including the vmapped (K>1) program
# ---------------------------------------------------------------------------

def _proto(algo=Algo.RENO, variant=Variant.WI, **kw):
    return MLTCPConfig(cc=CCParams(algo=int(algo), variant=int(variant),
                                   tick_dt=DT, rtt=100e-6),
                       slope=1.75, intercept=0.25, **kw)


def _cfg(n_jobs=2, sim_time=0.3, seed=3, **kw):
    topo = netsim.dumbbell(n_jobs, sockets_per_job=2)
    jobs = netsim.JobSpec.simple([0.0075] * n_jobs, [25e6] * n_jobs)
    return netsim.SimConfig(topo=topo, jobs=jobs,
                            protocol=kw.pop("protocol", _proto()),
                            sim_time=sim_time, dt=DT, seed=seed, **kw)


@pytest.mark.parametrize("variant", [Variant.WI, Variant.MD, Variant.BOTH])
def test_real_kernel_body_is_clean(variant):
    cfg = _cfg(use_pallas_kernel=True,
               protocol=_proto(variant=variant))
    sweep = engine.make_sweep(cfg)
    findings, facts = lint_kernel(cfg, sweep, label="real")
    assert findings == []
    assert facts["kernel_checked"]
    assert facts["vmem_bytes_per_step"] > 0


def test_real_kernel_body_clean_under_vmap():
    cfg = _cfg(use_pallas_kernel=True)
    sweep = engine.make_sweep(cfg, seed=[1, 2, 3])
    findings, facts = lint_kernel(cfg, sweep, label="vmapped")
    assert findings == []
    assert facts["kernel_checked"]


def test_kernel_lint_skips_oracle_configs():
    cfg = _cfg()                               # use_pallas_kernel=False
    findings, facts = lint_kernel(cfg, engine.make_sweep(cfg), label="off")
    assert findings == [] and not facts["kernel_checked"]


def test_expected_layout_matches_ops_packing():
    lay = ops.kernel_layout(100)
    assert lay.rows == ops.packed_rows(100)
    assert lay.rows % lay.block[0] == 0
    assert lay.grid == (lay.rows // lay.block[0],)
    assert lay.n_inputs == 1 + len(ms.IN_ORDER)
    assert ops.kernel_layout(100, use_static_factors=True).n_inputs == \
        2 + len(ms.IN_ORDER)


# ---------------------------------------------------------------------------
# HLO budget bookkeeping
# ---------------------------------------------------------------------------

_SIG = "group0|jobs=2 flows=4 algo=0 dt=2e-05 kernel=True faults=False"


def _envelope(**over):
    env = {m: 100.0 for m in METRICS}
    env.update(over)
    return env


def _write_baseline(path, groups, env=None, tolerances=None):
    path.write_text(json.dumps({
        "schema": SCHEMA,
        "env": env or env_fingerprint(),
        "tolerances": tolerances or {},
        "plans": {"p": {"groups": groups}},
    }))


def test_budget_clean_when_within_tolerance(tmp_path):
    bp = tmp_path / "budgets.json"
    _write_baseline(bp, [dict(signature=_SIG, **_envelope())])
    book = BudgetBook(path=bp)
    book.observe("p", _SIG, _envelope(flops=101.0))     # within 2%
    assert book.finish() == []


def test_tampered_baseline_trips_drift_with_group_and_metric(tmp_path):
    bp = tmp_path / "budgets.json"
    _write_baseline(bp, [dict(signature=_SIG, **_envelope())])
    book = BudgetBook(path=bp)
    book.observe("p", _SIG, _envelope(flops=150.0, output_bytes=101.0))
    findings = book.finish()
    assert _rules(findings) == {"budget/drift"}
    drifted = {f.message.split(":")[0] for f in findings}
    assert drifted == {"flops", "output_bytes"}         # leaf-level diff
    assert all(f.where == f"p :: {_SIG}" for f in findings)


def test_missing_and_stale_baseline(tmp_path):
    bp = tmp_path / "budgets.json"
    _write_baseline(bp, [dict(signature="group9|gone", **_envelope())])
    book = BudgetBook(path=bp)
    book.observe("p", _SIG, _envelope())
    got = _rules(book.finish())
    assert got == {"budget/missing-baseline", "budget/stale-baseline"}


def test_env_mismatch_skips_drift(tmp_path):
    bp = tmp_path / "budgets.json"
    _write_baseline(bp, [dict(signature=_SIG, **_envelope())],
                    env={"jax": "0.0.0"})
    book = BudgetBook(path=bp)
    book.observe("p", _SIG, _envelope(flops=1e9))       # huge drift...
    got = _rules(book.finish())
    assert got == {"budget/env-mismatch"}               # ...but skipped


def test_no_baseline_file_warns(tmp_path):
    book = BudgetBook(path=tmp_path / "nope.json")
    book.observe("p", _SIG, _envelope())
    findings = book.finish()
    assert _rules(findings) == {"budget/missing-baseline"}
    assert "does not exist" in findings[0].message


def test_unknown_dtype_surfaces_as_finding(tmp_path):
    bp = tmp_path / "budgets.json"
    _write_baseline(bp, [dict(signature=_SIG, **_envelope())])
    book = BudgetBook(path=bp)
    book.observe("p", _SIG, dict(_envelope(), unknown_dtypes=["q7"]))
    got = _rules(book.finish())
    assert "budget/unknown-dtype" in got


def test_update_mode_roundtrips(tmp_path):
    bp = tmp_path / "budgets.json"
    book = BudgetBook(path=bp, update=True)
    book.observe("p", _SIG, _envelope(flops=42.0))
    book.save()
    data = json.loads(bp.read_text())
    assert data["schema"] == SCHEMA
    assert data["env"] == env_fingerprint()
    (group,) = data["plans"]["p"]["groups"]
    assert group["signature"] == _SIG and group["flops"] == 42.0
    # and a check-mode book against it is clean
    book2 = BudgetBook(path=bp)
    book2.observe("p", _SIG, _envelope(flops=42.0))
    assert book2.finish() == []


def test_matches_any_cross_check(tmp_path):
    bp = tmp_path / "budgets.json"
    _write_baseline(bp, [dict(signature=_SIG, **_envelope())])
    book = BudgetBook(path=bp)
    bare_sig = _SIG.split("|", 1)[1]
    assert book.matches_any(bare_sig, _envelope()) is True
    assert book.matches_any(bare_sig, _envelope(flops=999.0)) is False
    assert book.matches_any("jobs=9 flows=9", _envelope()) is None


def test_committed_budgets_schema_is_current():
    from repro.analysis.hlo_budget import DEFAULT_PATH

    data = json.loads(DEFAULT_PATH.read_text())
    assert data["schema"] == SCHEMA
    assert set(data["plans"])  # at least one plan pinned
    for plan in data["plans"].values():
        for g in plan["groups"]:
            assert set(METRICS) <= set(g)


# ---------------------------------------------------------------------------
# roofline dtype regression (satellite): fabricated HLO lines
# ---------------------------------------------------------------------------

def test_f8_collective_bytes_counted_exactly():
    txt = "%ar = f8e4m3[128]{0} all-reduce(%x), replica_groups={}"
    out = hlo.collective_bytes_from_text(txt)
    assert out["total_bytes"] == 128.0                  # 1 B/elem, not 4
    assert out["unknown_dtypes"] == []


def test_unknown_dtype_warns_once_and_is_reported():
    hlo._warned_dtypes.discard("q7")
    txt = ("%a = q7[64]{0} all-gather(%x)\n"
           "%b = q7[64]{0} all-gather(%y)\n")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = hlo.collective_bytes_from_text(txt)
    assert out["unknown_dtypes"] == ["q7"]
    assert out["total_bytes"] == 2 * 64 * 4             # documented default
    assert sum("q7" in str(x.message) for x in w) == 1  # once, not per line
