"""On-device probe subsystem (`netsim.telemetry`) — the off-is-free
invariant, decimation correctness, detector == NumPy replay, trace-count
pinning, and the plan-layer plumbing (telemetry=, profile=, cache
versioning, per-plan fallback-warning reset)."""
import dataclasses
import math
import os

import jax
import numpy as np
import pytest

from repro import netsim
from repro.netsim import engine, telemetry
from repro.core import Algo, CCParams, MLTCPConfig, Variant

DT = 2e-5


def _proto(algo=Algo.RENO, variant=Variant.WI, **kw):
    return MLTCPConfig(cc=CCParams(algo=int(algo), variant=int(variant),
                                   tick_dt=DT, rtt=100e-6),
                       slope=1.75, intercept=0.25, **kw)


def _cfg(n_jobs=2, sim_time=0.2, seed=3, **kw):
    topo = netsim.dumbbell(n_jobs, sockets_per_job=2)
    jobs = netsim.JobSpec.simple([0.004] * n_jobs, [2e6] * n_jobs)
    return netsim.SimConfig(topo=topo, jobs=jobs,
                            protocol=kw.pop("protocol", _proto()),
                            sim_time=sim_time, dt=DT, seed=seed, **kw)


ALL_PROBES = ("flow_cwnd", "flow_rate", "flow_ratio", "link_queue",
              "link_mark_rate", "job_incomm", "job_phase", "job_iter",
              "job_f", "interleave_overlap")


# ---------------------------------------------------------------------------
# (a) telemetry off is free
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", [Algo.RENO, Algo.CUBIC, Algo.DCQCN])
def test_off_bit_identical_and_armed_changes_nothing(algo):
    """Arming every probe + detector must not perturb a single bit of the
    pre-existing outputs, and the unarmed config's telemetry stays None."""
    cfg = _cfg(protocol=_proto(algo=algo))
    raw_off = netsim.simulate(cfg)
    assert raw_off.telemetry is None
    assert raw_off.final_state.telemetry is None

    cfg_on = dataclasses.replace(
        cfg, telemetry=telemetry.TelemetrySpec(probes=ALL_PROBES, stride=40))
    raw_on = netsim.simulate(cfg_on)
    assert raw_on.telemetry is not None
    for f in engine.RawSimOutput._fields:
        if f in ("final_state", "telemetry"):
            continue
        assert np.array_equal(np.asarray(getattr(raw_off, f)),
                              np.asarray(getattr(raw_on, f)),
                              equal_nan=True), f


def test_off_output_has_no_extra_leaves():
    """None telemetry contributes zero pytree leaves: an unarmed run's
    output tree is leaf-identical to the pre-subsystem layout."""
    cfg = _cfg(sim_time=0.05)
    raw = netsim.simulate(cfg)
    stripped = raw._replace(final_state=None, telemetry=None)
    n_chunk_fields = len(telemetry.CHUNK_PROBES)
    # iter_times + iter_counts + the chunk trace channels
    assert len(jax.tree_util.tree_leaves(stripped)) == 2 + n_chunk_fields


def test_off_rerun_does_not_retrace():
    cfg = _cfg(sim_time=0.05)
    sweep = netsim.make_sweep(cfg, seed=(1, 2))
    netsim.simulate_sweep(cfg, sweep)
    before = engine.TRACE_COUNT
    netsim.simulate_sweep(cfg, netsim.make_sweep(cfg, seed=(3, 4)))
    assert engine.TRACE_COUNT == before


# ---------------------------------------------------------------------------
# (b) decimated series == dense stride-1 reference at the sampled ticks
# ---------------------------------------------------------------------------

def test_decimated_equals_dense_restriction():
    stride = 37            # deliberately not a divisor of anything
    probes = ("flow_cwnd", "link_queue", "job_incomm", "job_f")
    base = _cfg(sim_time=0.05)
    dense_cfg = dataclasses.replace(
        base, telemetry=telemetry.TelemetrySpec(probes=probes, stride=1,
                                                detectors=()))
    dec_cfg = dataclasses.replace(
        base, telemetry=telemetry.TelemetrySpec(probes=probes, stride=stride,
                                                detectors=()))
    dense = telemetry.collect(dense_cfg, netsim.simulate(dense_cfg).telemetry)
    dec = telemetry.collect(dec_cfg, netsim.simulate(dec_cfg).telemetry)
    assert np.array_equal(dec.ticks, dense.ticks[::stride])
    for name in probes:
        assert np.array_equal(dec.series[name], dense.series[name][::stride]), name


def test_ring_buffer_wraps_chronologically():
    """capacity < samples: the ring keeps the *latest* window, and collect
    returns it in tick order."""
    cfg = _cfg(sim_time=0.05)
    cap = 13
    cfg = dataclasses.replace(
        cfg, telemetry=telemetry.TelemetrySpec(probes=("job_iter",),
                                               stride=10, capacity=cap,
                                               detectors=()))
    res = telemetry.collect(cfg, netsim.simulate(cfg).telemetry)
    n_ticks = cfg.n_ticks
    sampled = np.arange(0, n_ticks, 10)
    assert np.array_equal(res.ticks, sampled[-cap:])
    assert res.n_samples == len(sampled)


# ---------------------------------------------------------------------------
# (c) in-scan detectors == NumPy post-hoc replay
# ---------------------------------------------------------------------------

def test_interleave_detector_matches_numpy_replay():
    spec = telemetry.TelemetrySpec(probes=("job_incomm", "job_iter"),
                                   stride=1)
    cfg = dataclasses.replace(_cfg(), telemetry=spec)
    raw = netsim.simulate(cfg)
    ic = np.asarray(raw.telemetry.series["job_incomm"]) > 0.5
    ji = np.asarray(raw.telemetry.series["job_iter"])

    # float32 replay of the streaming EWMA both/either ratio
    alpha = np.float32(-math.expm1(-cfg.dt / spec.overlap_tau))
    a, b = ic[:, 0], ic[:, 1]
    eb = ee = np.float32(0.0)
    last_bad, iters_at = -1, 0
    for t in range(len(a)):
        eb = eb + alpha * (np.float32(a[t] & b[t]) - eb)
        ee = ee + alpha * (np.float32(a[t] | b[t]) - ee)
        ov = eb / max(ee, np.float32(1e-6))
        if ov > spec.overlap_threshold:
            last_bad, iters_at = t, ji[t].max()
    assert int(raw.telemetry.last_bad_tick) == last_bad
    assert int(raw.telemetry.iters_at_last_bad) == int(iters_at)

    res = telemetry.collect(cfg, raw.telemetry)
    hold = int(round(spec.hold_frac * cfg.n_ticks))
    if last_bad < cfg.n_ticks - hold:
        assert res.converged
        assert res.time_to_interleave_s == pytest.approx((last_bad + 1) * cfg.dt)
        assert res.time_to_interleave_iters == float(iters_at)
    else:
        assert not res.converged
        assert res.time_to_interleave_s == float("inf")


def test_iter_sketch_quantiles_match_percentile():
    """Streaming p50/p99 from the log-histogram sketch lands within one
    bin width of the exact percentile over the recorded iterations."""
    spec = telemetry.TelemetrySpec(probes=(), detectors=("iter_sketch",))
    cfg = dataclasses.replace(_cfg(sim_time=0.4), telemetry=spec)
    res = netsim.postprocess(cfg, netsim.simulate(cfg))
    exact = np.concatenate(res.iter_times)
    assert int(res.telemetry.iter_hist.sum()) == exact.size
    ratio = spec.sketch_hi / spec.sketch_lo
    bin_w = ratio ** (1.0 / spec.sketch_bins)     # geometric bin width
    for q in (0.5, 0.99):
        sk = res.telemetry.iter_quantile(q)
        ex = float(np.quantile(exact, q))
        assert ex / bin_w <= sk <= ex * bin_w


# ---------------------------------------------------------------------------
# (d) trace accounting: armed probes cost exactly one trace per group
# ---------------------------------------------------------------------------

def test_armed_plan_one_trace_per_group_and_rerun_free():
    spec = telemetry.TelemetrySpec(stride=50)
    plan = netsim.Plan(
        name="tele-trace",
        axes=(netsim.Axis("variant", ("OFF", "WI")),
              netsim.Axis("seed", (1, 2))),
        build=lambda pt: _cfg(sim_time=0.05, protocol=_proto(
            variant=Variant[pt["variant"]])))
    before = engine.TRACE_COUNT
    pr = netsim.run_plan(plan, telemetry=spec)
    assert pr.n_compile_groups == 2
    assert engine.TRACE_COUNT - before == 2
    assert all(r.telemetry is not None for r in pr)
    # rerun: jit cache holds both armed programs — zero new traces
    before = engine.TRACE_COUNT
    netsim.run_plan(plan, telemetry=spec)
    assert engine.TRACE_COUNT == before
    # profile per group recorded on the default path
    assert len(pr.profile.groups) == 2
    assert all(g.wall_s > 0 for g in pr.profile.groups)


def test_padded_group_trims_point_telemetry():
    """On a padded-jobs group, each point's series trim to its own fabric."""
    spec = telemetry.TelemetrySpec(probes=("flow_cwnd", "job_incomm"),
                                   stride=50)

    def build(pt):
        n = pt["n_jobs"]
        topo = netsim.dumbbell(n, sockets_per_job=2)
        jobs = netsim.JobSpec.simple([0.004] * n, [2e6] * n)
        return netsim.SimConfig(topo=topo, jobs=jobs, protocol=_proto(),
                                sim_time=0.05, dt=DT, seed=3)

    plan = netsim.Plan(name="tele-pad",
                       axes=(netsim.Axis("n_jobs", (2, 3)),), build=build)
    pr = netsim.run_plan(plan, telemetry=spec)
    assert pr.n_compile_groups == 1          # padded into one group
    for r in pr:
        n = r.point["n_jobs"]
        assert r.telemetry.series["job_incomm"].shape[1] == n
        assert r.telemetry.series["flow_cwnd"].shape[1] == 2 * n


# ---------------------------------------------------------------------------
# registry & spec validation
# ---------------------------------------------------------------------------

def test_unknown_probe_rejected_and_custom_probe_captured():
    cfg = dataclasses.replace(
        _cfg(), telemetry=telemetry.TelemetrySpec(probes=("no_such",)))
    with pytest.raises(ValueError, match="no_such"):
        netsim.simulate(cfg)

    name = "test_q_sq"
    telemetry.register_probe(name, "link", lambda s: s.q_len ** 2,
                             overwrite=True)
    spec = telemetry.TelemetrySpec(probes=(name, "link_queue"), stride=25,
                                   detectors=())
    cfg = dataclasses.replace(_cfg(sim_time=0.05), telemetry=spec)
    res = telemetry.collect(cfg, netsim.simulate(cfg).telemetry)
    assert np.array_equal(res.series[name], res.series["link_queue"] ** 2)


def test_probe_timeline_accessors():
    spec = telemetry.TelemetrySpec(stride=50)
    cfg = dataclasses.replace(_cfg(), telemetry=spec)
    res = netsim.postprocess(cfg, netsim.simulate(cfg))
    t, cw = netsim.probe_timeline(res, "flow_cwnd")
    assert t.shape[0] == cw.shape[0] and cw.shape[1] == cfg.topo.n_flows
    assert np.isfinite(netsim.time_to_interleave(res)) in (True, False)
    with pytest.raises(KeyError, match="job_f"):
        netsim.probe_timeline(res, "job_f")    # not armed by default
    off = netsim.postprocess(_cfg(sim_time=0.05), netsim.simulate(
        _cfg(sim_time=0.05)))
    with pytest.raises(ValueError, match="telemetry"):
        netsim.time_to_interleave(off)


# ---------------------------------------------------------------------------
# plan layer: profiling, cache versioning, warning reset
# ---------------------------------------------------------------------------

def _mini_plan(**build_kw):
    kw = {"sim_time": 0.05, **build_kw}
    return netsim.Plan(name="mini",
                       axes=(netsim.Axis("seed", (1, 2)),),
                       build=lambda pt: _cfg(**kw))


def test_profile_split_fields():
    pr = netsim.run_plan(_mini_plan(), profile=True)
    (g,) = pr.profile.groups
    assert g.trace_s is not None and g.compile_s is not None
    assert g.execute_s is not None and g.wall_s > 0
    assert g.n_points == 2 and g.n_ticks == 2500
    s = pr.profile.summary()
    assert s["n_groups"] == 1 and "compile_s" in s
    # default path: split fields stay None
    pr2 = netsim.run_plan(_mini_plan())
    assert pr2.profile.groups[0].compile_s is None
    assert pr2.profile.total_ticks == 2 * 2500


def test_cache_versioned_and_pruned(tmp_path):
    cache = str(tmp_path)
    # stale v1-layout and torn entries must be evicted, current kept
    open(os.path.join(cache, "0123abcd.pkl"), "wb").close()
    open(os.path.join(cache, "v2-torn.pkl.tmp"), "wb").close()
    pr = netsim.run_plan(_mini_plan(), cache_dir=cache)
    assert pr.n_cache_hits == 0
    fresh = [n for n in os.listdir(cache) if n.endswith(".pkl")
             and n.startswith("v2-")]
    assert len(fresh) == 2
    assert netsim.prune_cache(cache) == 2
    assert sorted(os.listdir(cache)) == sorted(fresh)
    pr2 = netsim.run_plan(_mini_plan(), cache_dir=cache)
    assert pr2.n_cache_hits == 2 and pr2.n_compile_groups == 0


def test_fallback_warning_rearmed_per_plan():
    """A plan whose kernel-enabled config falls back must warn even when an
    earlier plan already warned for the same reason."""
    pytest.importorskip("repro.kernels.ops")
    kw = dict(protocol=_proto(favoritism="smallest_data_remaining"),
              use_pallas_kernel=True)
    with pytest.warns(UserWarning, match="favoritism"):
        pr = netsim.run_plan(_mini_plan(**kw))
    assert pr.n_kernel_fallbacks >= 1
    # a *different static config* (new trace) with the same fallback reason:
    # without the per-plan reset, the process-global once-guard would
    # swallow this plan's warning
    with pytest.warns(UserWarning, match="favoritism"):
        netsim.run_plan(_mini_plan(sim_time=0.06, **kw))
