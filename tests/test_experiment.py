"""Experiment-plan API (`netsim.experiment`) — correctness invariants.

The contract: a plan's cartesian product partitions into compile groups
(one trace per distinct static signature), job-count grids merge into one
padded + masked group whose active lanes match unpadded runs exactly, and
every result is self-describing via its `SweepPoint`.
"""
import dataclasses

import numpy as np
import pytest

from repro import netsim
from repro.netsim import engine, experiment
from repro.core import Algo, CCParams, MLTCPConfig, Variant

DT = 2e-5


def _proto(algo=Algo.RENO, variant=Variant.WI, **kw):
    return MLTCPConfig(cc=CCParams(algo=int(algo), variant=int(variant),
                                   tick_dt=DT, rtt=100e-6),
                       slope=1.75, intercept=0.25, **kw)


def _cfg(n_jobs=2, sim_time=0.4, seed=3, **kw):
    topo = netsim.dumbbell(n_jobs, sockets_per_job=2)
    jobs = netsim.JobSpec.simple([0.0075] * n_jobs, [25e6] * n_jobs)
    return netsim.SimConfig(topo=topo, jobs=jobs,
                            protocol=kw.pop("protocol", _proto()),
                            sim_time=sim_time, dt=DT, seed=seed, **kw)


def _jobs_plan(variants=("WI",), job_counts=(2, 3, 4), seeds=(3,),
               sim_time=0.4, name="jobs-plan"):
    def build(pt):
        variant = {"OFF": Variant.OFF, "WI": Variant.WI}[pt["variant"]]
        return _cfg(n_jobs=pt["n_jobs"], sim_time=sim_time,
                    protocol=_proto(variant=variant))
    return netsim.Plan(
        name=name, build=build,
        axes=(netsim.Axis("variant", tuple(variants)),
              netsim.Axis("n_jobs", tuple(job_counts)),
              netsim.Axis("seed", tuple(seeds))))


# ---------------------------------------------------------------------------
# Padded / masked jobs axis
# ---------------------------------------------------------------------------

def test_padded_job_axis_matches_unpadded_runs():
    """A plan over n_jobs in {2,3,4} must match three unpadded `simulate()`
    runs on iteration times (tight tolerance)."""
    counts = (2, 3, 4)
    pr = netsim.run_plan(_jobs_plan(job_counts=counts), shard=False)
    assert pr.n_compile_groups == 1
    for n in counts:
        (res,) = pr.select(n_jobs=n)
        assert res.n_jobs == n            # padded jobs trimmed away
        cfg = _cfg(n_jobs=n)
        seq = netsim.postprocess(cfg, netsim.simulate(cfg))
        assert len(seq.iter_times) == n
        for j in range(n):
            assert res.iter_times[j].shape == seq.iter_times[j].shape
            np.testing.assert_allclose(res.iter_times[j], seq.iter_times[j],
                                       rtol=1e-5, atol=1e-7)


def test_padded_group_compiles_once():
    """The whole job-count grid is one trace of one compile group."""
    before = engine.TRACE_COUNT
    pr = netsim.run_plan(_jobs_plan(job_counts=(2, 3, 4), sim_time=0.1,
                                    name="trace-once"), shard=False)
    assert pr.n_compile_groups == 1
    assert engine.TRACE_COUNT == before + 1


def test_fig10_style_plan_two_compile_groups():
    """Acceptance: job count 2..8 x 3 seeds x {MLTCP, OFF} runs in <= 2
    compile groups (one per variant) instead of >= 14 compiles."""
    before = engine.TRACE_COUNT
    pr = netsim.run_plan(_jobs_plan(variants=("OFF", "WI"),
                                    job_counts=(2, 3, 4, 5, 6, 7, 8),
                                    seeds=(1, 2, 3), sim_time=0.3,
                                    name="fig10-accept"), shard=False)
    assert len(pr) == 2 * 7 * 3
    assert pr.n_compile_groups <= 2
    assert engine.TRACE_COUNT - before <= 2
    # every result is self-describing
    for res in pr:
        assert res.point is not None
        assert set(res.point.axes) == {"variant", "n_jobs", "seed"}
        assert res.n_jobs == res.point["n_jobs"]
        assert res.point.params.job_active is not None
    # seed-paired selections feed the error-bar aggregation directly
    sp = netsim.sweep_speedup_stats(pr.select(variant="OFF", n_jobs=5),
                                    pr.select(variant="WI", n_jobs=5))
    assert sp["n_points"] == 3


def test_pad_jobs_off_forces_exact_groups():
    pr = netsim.run_plan(_jobs_plan(job_counts=(2, 3), sim_time=0.1,
                                    name="no-pad"),
                         shard=False, pad_jobs=False)
    assert pr.n_compile_groups == 2


def test_mismatched_workload_structure_does_not_merge():
    """Points whose jobs differ *structurally* (start offsets, phase counts)
    keep their own compile group — only workload values are traced."""
    def build(pt):
        n = pt["n_jobs"]
        offs = [0.002] * n if n == 3 else None      # structural difference
        topo = netsim.dumbbell(n, sockets_per_job=2)
        jobs = netsim.JobSpec.simple([0.0075] * n, [25e6] * n,
                                     start_offset=offs)
        return netsim.SimConfig(topo=topo, jobs=jobs, protocol=_proto(),
                                sim_time=0.1, dt=DT, seed=0)
    pr = netsim.run_plan(netsim.Plan(
        name="mismatch", build=build,
        axes=(netsim.Axis("n_jobs", (2, 3)),)), shard=False)
    assert pr.n_compile_groups == 2


def test_workload_values_merge_into_one_group():
    """Jobs differing only in compute/comm/straggle *values* are traced
    leaves now: one compile group, results bit-equal to exact grouping."""
    def build(pt):
        n = pt["n_jobs"]
        compute = [0.0075] * n if n == 3 else [0.009] * n   # value-only diff
        topo = netsim.dumbbell(n, sockets_per_job=2)
        jobs = netsim.JobSpec.simple(compute, [25e6] * n,
                                     straggle_prob=[0.05 * (n == 3)] * n)
        return netsim.SimConfig(topo=topo, jobs=jobs, protocol=_proto(),
                                sim_time=0.1, dt=DT, seed=0)
    plan = netsim.Plan(name="value-merge", build=build,
                       axes=(netsim.Axis("n_jobs", (2, 3)),))
    before = engine.TRACE_COUNT
    pr = netsim.run_plan(plan, shard=False)
    assert pr.n_compile_groups == 1
    assert engine.TRACE_COUNT == before + 1
    # bit-identical to per-cell compilation
    pr_exact = netsim.run_plan(plan, shard=False, pad_jobs=False)
    assert pr_exact.n_compile_groups == 2
    for a, b in zip(pr, pr_exact):
        assert a.point.axes == b.point.axes
        for ja, jb in zip(a.iter_times, b.iter_times):
            assert np.array_equal(ja, jb)


def test_run_plan_cache_resumes(tmp_path):
    """Satellite: SweepPoint-keyed on-disk cache makes plans resumable —
    second run is all hits, a deleted entry re-simulates just that point,
    and cached results are bit-identical to fresh ones."""
    cache = str(tmp_path / "plan-cache")
    plan = _jobs_plan(job_counts=(2, 3), seeds=(0, 1), sim_time=0.1,
                      name="cached")
    fresh = netsim.run_plan(plan, shard=False, cache_dir=cache)
    assert fresh.n_cache_hits == 0 and fresh.n_compile_groups == 1
    rerun = netsim.run_plan(plan, shard=False, cache_dir=cache)
    assert rerun.n_cache_hits == len(rerun)
    assert rerun.n_compile_groups == 0          # nothing left to simulate
    for a, b in zip(fresh, rerun):
        assert a.point.axes == b.point.axes
        for ja, jb in zip(a.iter_times, b.iter_times):
            assert np.array_equal(ja, jb)
    # drop one entry -> exactly one point re-simulates
    victims = sorted((tmp_path / "plan-cache").glob("*.pkl"))
    victims[0].unlink()
    partial = netsim.run_plan(plan, shard=False, cache_dir=cache)
    assert partial.n_cache_hits == len(partial) - 1
    assert partial.n_compile_groups == 1


# ---------------------------------------------------------------------------
# Axes: dynamic vs static, resolve, where
# ---------------------------------------------------------------------------

def test_dynamic_axes_share_one_group_static_axes_split():
    cfg = _cfg(sim_time=0.1)
    before = engine.TRACE_COUNT
    pr = netsim.run_plan(netsim.Plan(
        name="axes", build=lambda pt: dataclasses.replace(
            cfg, protocol=dataclasses.replace(cfg.protocol,
                                              f_spec=pt["f_spec"])),
        axes=(netsim.Axis("f_spec", ("F1", "F5")),       # static: 2 groups
              netsim.Axis("slope", (0.5, 1.75)),         # dynamic
              netsim.Axis("seed", (0, 1)))), shard=False)
    assert pr.n_compile_groups == 2
    assert engine.TRACE_COUNT == before + 2
    assert len(pr.select(f_spec="F1")) == 4
    # the dynamic value actually reached the sweep
    (res,) = pr.select(f_spec="F1", slope=0.5, seed=1)
    assert float(res.point.params.slope) == 0.5
    assert int(res.point.params.seed) == 1


def test_axis_resolve_maps_labels_to_masks():
    """A label axis can resolve to job_active masks (isolation runs) and
    stay selectable by label."""
    def solo_mask(v):
        if v == "all":
            return np.ones((2,), bool)
        m = np.zeros((2,), bool)
        m[v] = True
        return m
    pr = netsim.run_plan(netsim.Plan(
        name="solo", build=lambda pt: _cfg(sim_time=0.4),
        axes=(netsim.Axis("solo", ("all", 0, 1), field="job_active",
                          resolve=solo_mask),)), shard=False)
    assert pr.n_compile_groups == 1
    (alone,) = pr.select(solo=0)
    assert len(alone.iter_times[0]) > 0
    assert len(alone.iter_times[1]) == 0       # masked job never ran
    (both,) = pr.select(solo="all")
    assert all(len(x) > 0 for x in both.iter_times)
    # isolation is at least as fast as sharing the link
    assert alone.avg_iter(0) <= both.avg_iter(0) * 1.01


def test_where_prunes_points():
    pr = netsim.run_plan(netsim.Plan(
        name="where", build=lambda pt: _cfg(sim_time=0.1),
        axes=(netsim.Axis("a", (0, 1)), netsim.Axis("seed", (0, 1))),
        where=lambda pt: not (pt["a"] == 1 and pt["seed"] == 1)),
        shard=False)
    assert len(pr) == 3
    with pytest.raises(KeyError):
        pr.select(a=1, seed=1)


def test_plan_validation():
    with pytest.raises(ValueError, match="duplicate axis"):
        netsim.Plan(name="dup", build=lambda pt: _cfg(),
                    axes=(netsim.Axis("a", (1,)), netsim.Axis("a", (2,))))
    with pytest.raises(ValueError, match="no values"):
        netsim.Axis("empty", ())
    with pytest.raises(ValueError, match="unknown kind"):
        netsim.Axis("a", (1,), kind="bogus")
    with pytest.raises(ValueError, match="unknown sweep field"):
        netsim.run_plan(netsim.Plan(
            name="bad-field", build=lambda pt: _cfg(sim_time=0.1),
            axes=(netsim.Axis("a", (1,), kind="dynamic"),)), shard=False)


# ---------------------------------------------------------------------------
# Self-describing results / SweepPoint round-trip
# ---------------------------------------------------------------------------

def test_grid_sweep_points_roundtrip_through_postprocess():
    """grid_sweep labels travel attached to results, not positionally."""
    cfg = _cfg(sim_time=0.3)
    slopes = [0.5, 1.75]
    sweep, points = netsim.grid_sweep(cfg, slope=slopes, seed=[0, 1])
    assert all(isinstance(p, netsim.SweepPoint) for p in points)
    results = netsim.postprocess_sweep(cfg, netsim.simulate_sweep(cfg, sweep),
                                       points)
    for res in results:
        assert res.point is not None
        # the label matches the params that actually ran
        assert float(res.point.params.slope) == res.point["slope"]
        assert int(res.point.params.seed) == res.point["seed"]
    assert sorted({r.point["slope"] for r in results}) == slopes
    with pytest.raises(ValueError, match="points for a K="):
        netsim.postprocess_sweep(cfg, netsim.simulate_sweep(cfg, sweep),
                                 points[:1])


def test_sweep_point_matches_and_group_by():
    pr = netsim.run_plan(_jobs_plan(job_counts=(2, 3), seeds=(0, 1),
                                    sim_time=0.1, name="pivot"), shard=False)
    assert pr[0].point.matches(variant="WI")
    assert not pr[0].point.matches(variant="OFF")
    assert not pr[0].point.matches(bogus=1)
    by_n = pr.group_by("n_jobs")
    assert set(by_n) == {(2,), (3,)}
    assert all(len(v) == 2 for v in by_n.values())
    assert pr.n_ticks == sum(r.cfg.n_ticks for r in pr)


def test_restrict_workload_roundtrip():
    cfg4 = _cfg(n_jobs=4)
    cfg2 = _cfg(n_jobs=2)
    topo_r, jobs_r = netsim.restrict_workload(cfg4.topo, cfg4.jobs, 2)
    assert experiment._same_workload(topo_r, jobs_r, cfg2.topo, cfg2.jobs)
    assert not experiment._same_workload(topo_r, jobs_r,
                                         cfg4.topo, cfg4.jobs)


# ---------------------------------------------------------------------------
# Sharding
# ---------------------------------------------------------------------------

def test_shard_auto_is_safe_on_any_device_count():
    """shard="auto" partitions K when devices exist and is a no-op
    otherwise; results are identical either way."""
    pr_on = netsim.run_plan(_jobs_plan(job_counts=(2, 3), seeds=(0, 1, 2),
                                       sim_time=0.1, name="shard-on"),
                            shard=True)
    pr_off = netsim.run_plan(_jobs_plan(job_counts=(2, 3), seeds=(0, 1, 2),
                                        sim_time=0.1, name="shard-off"),
                             shard=False)
    assert len(pr_on) == len(pr_off)
    for a, b in zip(pr_on, pr_off):
        assert a.point.axes == b.point.axes
        np.testing.assert_allclose(np.concatenate(a.iter_times + [[0.0]]),
                                   np.concatenate(b.iter_times + [[0.0]]),
                                   rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# Cache-key hashing of non-finite / non-hashable leaves
# ---------------------------------------------------------------------------

def test_cache_key_nan_axes_do_not_collide():
    """NaN-bearing override arrays must key by NaN *position*, not collapse
    to one entry (the would-be cache aliasing bug) — and identical content
    must still key identically."""
    cfg = _cfg()
    a = np.array([np.nan, 1.0, 2.0])
    b = np.array([1.0, np.nan, 2.0])
    k_a = experiment._point_cache_key(cfg, {"x": a})
    k_b = experiment._point_cache_key(cfg, {"x": b})
    assert k_a != k_b
    assert k_a == experiment._point_cache_key(cfg, {"x": a.copy()})


def test_cache_key_nan_bit_patterns_canonicalize():
    """Two logically-identical configs whose NaNs carry different IEEE
    payload bits (0/0 vs float('nan') vs payload-poked) must share a key."""
    cfg = _cfg()
    a = np.array([np.nan, 3.0])
    b = a.copy()
    b.view(np.uint64)[0] |= 0xDEAD          # poke payload bits, still NaN
    assert np.isnan(b[0]) and a.tobytes() != b.tobytes()
    assert (experiment._point_cache_key(cfg, {"x": a})
            == experiment._point_cache_key(cfg, {"x": b}))
    # python-float NaN leaves canonicalize the same way
    assert (experiment._point_cache_key(cfg, {"x": float("nan")})
            == experiment._point_cache_key(cfg, {"x": np.float64("nan")}))


def test_cache_key_inf_signs_distinct():
    cfg = _cfg()
    assert (experiment._point_cache_key(cfg, {"x": float("inf")})
            != experiment._point_cache_key(cfg, {"x": float("-inf")}))


def test_cache_key_rejects_object_leaves():
    """Object arrays hash their element pointers — nondeterministic across
    processes — so they must raise instead of producing a silent bad key."""
    cfg = _cfg()
    with pytest.raises(TypeError, match="object"):
        experiment._point_cache_key(
            cfg, {"x": np.array([object(), object()], dtype=object)})
