"""Netsim engine invariants + the paper's headline system behaviours."""
import numpy as np
import pytest

from repro import netsim, workload
from repro.core import Algo, CCParams, MLTCPConfig, Variant

DT = 2e-5


def _proto(algo=Algo.RENO, variant=Variant.WI, **kw):
    defaults = {(int(Algo.RENO), int(Variant.WI)): (1.75, 0.25),
                (int(Algo.DCQCN), int(Variant.WI)): (1.067, 0.267)}
    s, i = defaults.get((int(algo), int(variant)), (1.75, 0.25))
    return MLTCPConfig(cc=CCParams(algo=int(algo), variant=int(variant),
                                   tick_dt=DT, rtt=100e-6),
                       slope=s, intercept=i, **kw)


def _run(topo, jobs, proto, sim_time=2.0, **kw):
    cfg = netsim.SimConfig(topo=topo, jobs=jobs, protocol=proto,
                           sim_time=sim_time, dt=DT, seed=3, **kw)
    return cfg, netsim.postprocess(cfg, netsim.simulate(cfg))


def test_single_job_achieves_near_line_rate_iterations():
    """One job alone: iteration time ~ compute + comm/line_rate."""
    topo = netsim.dumbbell(1, sockets_per_job=2)
    jobs = netsim.JobSpec.simple([0.01], [25e6])
    _, res = _run(topo, jobs, _proto())
    ideal = 0.01 + 25e6 / 6.25e9
    assert res.avg_iter(0) < ideal * 1.6, (res.avg_iter(0), ideal)
    assert len(res.iter_times[0]) > 50


def test_throughput_never_exceeds_capacity():
    topo = netsim.dumbbell(3, sockets_per_job=2)
    jobs = netsim.JobSpec.simple([0.005] * 3, [20e6] * 3)
    cfg, res = _run(topo, jobs, _proto())
    assert np.all(res.trace_util <= 1.0 + 1e-5)


def test_bytes_conservation():
    """Every completed iteration delivered exactly its job's bytes."""
    topo = netsim.dumbbell(2, sockets_per_job=2)
    jobs = netsim.JobSpec.simple([0.008, 0.008], [15e6, 15e6])
    cfg = netsim.SimConfig(topo=topo, jobs=jobs, protocol=_proto(),
                           sim_time=2.0, dt=DT, seed=0)
    raw = netsim.simulate(cfg)
    res = netsim.postprocess(cfg, raw)
    total_delivered = float(np.asarray(raw.trace_jobtput).sum()) \
        * (cfg.sim_time / raw.trace_jobtput.shape[0])
    iters_done = sum(len(x) for x in res.iter_times)
    # delivered >= completed iterations' bytes (plus in-flight partials)
    assert total_delivered >= iters_done * 15e6 * 0.95
    assert total_delivered <= (iters_done + 2) * 15e6 * 1.10


def test_mltcp_interleaves_and_speeds_up_reno():
    """Headline claim: MLTCP-Reno interleaves two jobs and beats Reno."""
    topo = netsim.dumbbell(2, sockets_per_job=2)
    jobs = netsim.JobSpec.simple([0.0075, 0.0075], [25e6, 25e6])
    _, base = _run(topo, jobs, _proto(variant=Variant.OFF), sim_time=3.0)
    _, ml = _run(topo, jobs, _proto(variant=Variant.WI), sim_time=3.0)
    assert netsim.mean_pairwise_interleave(ml) < 0.35
    assert netsim.mean_pairwise_interleave(ml) \
        < netsim.mean_pairwise_interleave(base)
    sp = netsim.speedup_stats(base, ml)
    assert sp["avg_speedup"] > 1.02, sp


def test_decreasing_f_does_not_interleave():
    """SRPT-canceling aggressiveness (F5) must fail (paper Fig 15)."""
    topo = netsim.dumbbell(2, sockets_per_job=2)
    jobs = netsim.JobSpec.simple([0.0075, 0.0075], [25e6, 25e6])
    _, f1 = _run(topo, jobs, _proto(f_spec="F1"), sim_time=3.0)
    _, f5 = _run(topo, jobs, _proto(f_spec="F5"), sim_time=3.0)
    assert netsim.mean_pairwise_interleave(f1) < \
        netsim.mean_pairwise_interleave(f5) - 0.1


def test_scale_invariance():
    """Scaling all durations/bytes together preserves relative speedups
    (justifies the benchmarks' WORK_SCALE)."""
    topo = netsim.dumbbell(2, sockets_per_job=2)

    def speedup(scale, sim_time):
        jobs = netsim.JobSpec.simple([0.01 * scale] * 2, [30e6 * scale] * 2)
        _, base = _run(topo, jobs, _proto(variant=Variant.OFF),
                       sim_time=sim_time)
        _, ml = _run(topo, jobs, _proto(variant=Variant.WI),
                     sim_time=sim_time)
        return netsim.speedup_stats(base, ml)["avg_speedup"]

    s1 = speedup(1.0, 4.0)
    s2 = speedup(2.0, 8.0)
    assert abs(s1 - s2) < 0.25, (s1, s2)


def test_straggler_injection_slows_iterations():
    topo = netsim.dumbbell(1, sockets_per_job=1)
    jobs_clean = netsim.JobSpec.simple([0.01], [10e6])
    jobs_strag = netsim.JobSpec.simple([0.01], [10e6],
                                       straggle_prob=[0.5])
    _, clean = _run(topo, jobs_clean, _proto())
    _, strag = _run(topo, jobs_strag, _proto())
    assert strag.avg_iter(0) > clean.avg_iter(0) * 1.01


def test_multi_peak_phase_program():
    """Hybrid jobs (multiple comm peaks per iteration) complete correctly."""
    topo = netsim.dumbbell(1, sockets_per_job=1)
    prof = workload.profile_for("gpt3_hybrid").scaled(0.2)
    jobs = workload.jobspec_from_profiles([prof])
    _, res = _run(topo, jobs, _proto())
    assert len(res.iter_times[0]) > 5
    iso = prof.iso_iter_time()
    assert res.avg_iter(0) >= iso * 0.9


def test_cassini_baseline_interleaves_compatible_jobs():
    topo = netsim.dumbbell(2, sockets_per_job=2)
    prof = workload.CommProfile("j", (0.0075,), (25e6,))
    sched, feasible = workload.cassini_schedule(topo, [prof, prof])
    assert feasible
    jobs = workload.jobspec_from_profiles([prof, prof])
    _, base = _run(topo, jobs, _proto(algo=Algo.DCQCN, variant=Variant.OFF),
                   sim_time=3.0)
    _, cas = _run(topo, jobs, _proto(algo=Algo.DCQCN, variant=Variant.OFF),
                  sim_time=3.0, cassini=sched)
    assert netsim.mean_pairwise_interleave(cas) <= \
        netsim.mean_pairwise_interleave(base) + 0.05


def test_engine_with_pallas_kernel_matches_jnp():
    """The fused-kernel engine path produces the same macro behaviour."""
    topo = netsim.dumbbell(2, sockets_per_job=1)
    jobs = netsim.JobSpec.simple([0.005, 0.005], [8e6, 8e6])
    _, a = _run(topo, jobs, _proto(), sim_time=1.0)
    cfg = netsim.SimConfig(topo=topo, jobs=jobs, protocol=_proto(),
                           sim_time=1.0, dt=DT, seed=3,
                           use_pallas_kernel=True)
    b = netsim.postprocess(cfg, netsim.simulate(cfg))
    assert abs(a.avg_iter(0) - b.avg_iter(0)) / a.avg_iter(0) < 1e-3
    assert len(a.iter_times[0]) == len(b.iter_times[0])
