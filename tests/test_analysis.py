"""Tests for the three-layer static verifier (repro.analysis).

Each lint rule is demonstrated to fire on a deliberately-broken fixture —
an injected f64 upcast in a scan body, a config that statically forces the
kernel->oracle fallback, a plan axis that needlessly splits compile groups,
source fixtures for every AST rule — and the real repo programs (reno /
cubic / dcqcn lowerings, armed telemetry, the benchmark plans' structure)
are asserted clean.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import netsim
from repro.analysis import (RULES, analyze_plan, kernel_expectation,
                            lint_closed_jaxpr, lint_plan, lint_sources,
                            lint_sweep, predict_compile_groups)
from repro.core import Algo, CCParams, MLTCPConfig, Variant
from repro.netsim import counters, engine

DT = 2e-5


def _proto(algo=Algo.RENO, variant=Variant.WI, **kw):
    return MLTCPConfig(cc=CCParams(algo=int(algo), variant=int(variant),
                                   tick_dt=DT, rtt=100e-6),
                       slope=1.75, intercept=0.25, **kw)


def _cfg(n_jobs=2, sim_time=0.3, seed=3, **kw):
    topo = netsim.dumbbell(n_jobs, sockets_per_job=2)
    jobs = netsim.JobSpec.simple([0.0075] * n_jobs, [25e6] * n_jobs)
    return netsim.SimConfig(topo=topo, jobs=jobs,
                            protocol=kw.pop("protocol", _proto()),
                            sim_time=sim_time, dt=DT, seed=seed, **kw)


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# IR lint: real lowerings are clean, broken fixtures fire
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", [Algo.RENO, Algo.CUBIC, Algo.DCQCN])
def test_real_lowerings_are_clean(algo):
    cfg = _cfg(protocol=_proto(algo=algo))
    findings, facts = lint_sweep(cfg, engine.make_sweep(cfg), label=str(algo))
    assert findings == []
    assert facts["expectation"] == "off"
    assert facts["pallas_calls"] == 0
    assert facts["f64_ops"] == 0
    assert facts["eqns"] > 0


def test_kernel_presence_statically_proven():
    cfg = _cfg(use_pallas_kernel=True)
    sweep = engine.make_sweep(cfg)
    assert kernel_expectation(cfg, sweep) == "fused"
    findings, facts = lint_sweep(cfg, sweep, label="fused")
    assert findings == []
    assert facts["pallas_calls"] >= 1


def test_kernel_fallback_config_fires():
    """Non-linear F without static factors is outside the kernel's
    specialization: requesting use_pallas_kernel must be flagged."""
    cfg = _cfg(use_pallas_kernel=True, protocol=_proto(f_spec="F3"))
    sweep = engine.make_sweep(cfg)
    assert kernel_expectation(cfg, sweep) == "fallback"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")      # ops.py's loud fallback warning
        findings, facts = lint_sweep(cfg, sweep, label="fb")
    assert "ir/kernel-fallback" in _rules(findings)
    assert facts["pallas_calls"] == 0        # and it really lowered unfused


def test_armed_telemetry_lowering_clean():
    spec = netsim.TelemetrySpec(probes=("flow_cwnd", "link_queue"),
                                stride=16)
    cfg = _cfg(telemetry=spec)
    findings, facts = lint_sweep(cfg, engine.make_sweep(cfg), label="armed")
    assert findings == []
    assert facts["f64_ops"] == 0


def test_f64_upcast_in_scan_body_fires():
    """A convert to float64 injected into a scan body must be caught (the
    x64 context synthesizes what jax_enable_x64 leakage would produce)."""
    def body(c, _):
        return c + jnp.float64(1.0), None

    with jax.experimental.enable_x64():
        jaxpr = jax.make_jaxpr(
            lambda x: jax.lax.scan(body, x, None, length=3))(
                jnp.zeros((), jnp.float64))
    findings, facts = lint_closed_jaxpr(jaxpr, label="f64-fixture")
    assert "ir/f64-promotion" in _rules(findings)
    assert facts["f64_ops"] > 0


def test_host_callback_in_scan_fires():
    def body(c, _):
        jax.debug.print("tick {}", c)
        return c + 1.0, None

    jaxpr = jax.make_jaxpr(
        lambda x: jax.lax.scan(body, x, None, length=2))(jnp.float32(0.0))
    findings, _ = lint_closed_jaxpr(jaxpr, label="cb-fixture")
    assert "ir/host-callback" in _rules(findings)


def test_nested_control_fires_and_whitelists():
    def body(c, _):
        c = jax.lax.cond(c > 0, lambda v: v + 1.0, lambda v: v - 1.0, c)
        return c, None

    jaxpr = jax.make_jaxpr(
        lambda x: jax.lax.scan(body, x, None, length=2))(jnp.float32(0.0))
    findings, _ = lint_closed_jaxpr(jaxpr, label="cond-fixture")
    assert "ir/nested-control" in _rules(findings)
    ok, _ = lint_closed_jaxpr(jaxpr, label="cond-ok",
                              whitelist=frozenset({"cond"}))
    assert "ir/nested-control" not in _rules(ok)


def test_kernel_unexpected_fires():
    """A pallas_call in a lowering that expected the oracle is flagged."""
    cfg = _cfg(use_pallas_kernel=True)
    traced = engine.trace_sweep(cfg, engine.make_sweep(cfg))
    findings, _ = lint_closed_jaxpr(traced.jaxpr, label="unexpected",
                                    expectation="off")
    assert "ir/kernel-unexpected" in _rules(findings)


# ---------------------------------------------------------------------------
# Plan lint: split explainers, avoidable splits, prediction == execution
# ---------------------------------------------------------------------------

def _variant_plan(**cfg_kw):
    def build(pt):
        var = {"OFF": Variant.OFF, "WI": Variant.WI}[pt["variant"]]
        return _cfg(protocol=_proto(variant=var), **cfg_kw)
    return netsim.Plan(name="variant-plan", build=build,
                       axes=(netsim.Axis("variant", ("OFF", "WI")),
                             netsim.Axis("seed", (3,))))


def test_group_split_explainer_names_the_field():
    findings, facts = lint_plan(_variant_plan(), label="vp")
    assert facts["groups"] == 2
    splits = [f for f in findings if f.rule == "plan/group-split"]
    assert len(splits) == 1
    assert "protocol.cc.variant" in splits[0].message
    # a structural split is not avoidable
    assert "plan/avoidable-split" not in _rules(findings)
    assert facts["wasted_traces_estimate"] == 0


def test_avoidable_split_fires_on_value_axis():
    """An axis over buffer_bytes (a plain float the canonicalizer keeps
    static) needlessly splits groups — flagged with a wasted-trace count."""
    def build(pt):
        return _cfg(buffer_bytes=pt["bb"])
    plan = netsim.Plan(name="bb-plan", build=build,
                       axes=(netsim.Axis("bb", (2e6, 4e6)),
                             netsim.Axis("seed", (3,))))
    findings, facts = lint_plan(plan, label="bb")
    assert facts["groups"] == 2
    avoid = [f for f in findings if f.rule == "plan/avoidable-split"]
    assert len(avoid) == 1
    assert "buffer_bytes" in avoid[0].message
    assert facts["wasted_traces_estimate"] == 1


def test_prediction_matches_execution():
    plan = _variant_plan()
    predicted = predict_compile_groups(plan)
    pr = netsim.run_plan(plan, shard=False)
    assert predicted == pr.n_compile_groups == 2


# ---------------------------------------------------------------------------
# Source lint fixtures (lint_sources): every AST rule fires, pragmas work
# ---------------------------------------------------------------------------

_SCANNED = """
import jax
import numpy as np
import jax.numpy as jnp

def body(c, x):
{body}
    return c, None

def run(xs):
    return jax.lax.scan(body, jnp.float32(0.0), xs)
"""


def _scan_fixture(body_lines):
    src = _SCANNED.format(body="\n".join("    " + l for l in body_lines))
    return lint_sources({"fix/mod.py": src})


def test_np_in_scan_fires_and_pragma_suppresses():
    findings, facts = _scan_fixture(["c = np.sin(c)"])
    assert "src/np-in-scan" in _rules(findings)
    assert facts["scan_reachable"] >= 1
    ok, _ = _scan_fixture(["c = np.sin(c)  # lint: allow(np-in-scan)"])
    assert "src/np-in-scan" not in _rules(ok)


def test_stale_pragma_unknown_rule_fires():
    findings, facts = _scan_fixture(
        ["c = c + 1  # lint: allow(no-such-rule)"])
    stale = [f for f in findings if f.rule == "src/stale-pragma"]
    assert stale and "unknown rule" in stale[0].message
    assert facts["pragmas"] == 1


def test_stale_pragma_unused_suppression_fires():
    # the named rule exists but nothing fires on that line
    findings, _ = _scan_fixture(["c = c + 1  # lint: allow(np-in-scan)"])
    stale = [f for f in findings if f.rule == "src/stale-pragma"]
    assert stale and "outlived" in stale[0].message


def test_stale_pragma_quiet_when_suppression_is_live():
    findings, _ = _scan_fixture(
        ["c = np.sin(c)  # lint: allow(np-in-scan)"])
    assert "src/stale-pragma" not in _rules(findings)


def test_float_cast_on_traced_fires():
    findings, _ = _scan_fixture(["y = jnp.sum(c)", "c = c + float(y)"])
    assert "src/float-cast-traced" in _rules(findings)
    # casting a static python value stays legal
    ok, _ = _scan_fixture(["n = len(x)", "c = c + float(n)"])
    assert "src/float-cast-traced" not in _rules(ok)


def test_branch_on_traced_fires():
    findings, _ = _scan_fixture(["y = jnp.sum(c)",
                                 "if y > 0:",
                                 "    c = c + 1"])
    assert "src/branch-on-traced" in _rules(findings)
    # `is None` tests and static-attribute branches stay legal
    ok, _ = _scan_fixture(["y = jnp.sum(c)",
                           "if y is not None and y.ndim == 0:",
                           "    c = c + 1"])
    assert "src/branch-on-traced" not in _rules(ok)


def test_f64_literal_rules():
    # jnp.float64 fires anywhere, even outside scan-reachable code
    findings, _ = lint_sources({"fix/a.py": (
        "import jax.numpy as jnp\n"
        "def helper(x):\n"
        "    return jnp.float64(x)\n")})
    assert "src/f64-literal" in _rules(findings)
    # np.float64 is legal numpy-side plumbing when not scan-reachable...
    ok, _ = lint_sources({"fix/b.py": (
        "import numpy as np\n"
        "def plumbing(x):\n"
        "    return np.float64(x)\n")})
    assert "src/f64-literal" not in _rules(ok)
    # ...but fires inside a scan-reachable function
    findings, _ = _scan_fixture(["c = c + np.float64(1.0)"])
    assert "src/f64-literal" in _rules(findings)


def test_unit_suffix_conflict_fires():
    findings, _ = lint_sources({"fix/u.py": (
        "def f(q_bytes, delay_s, rate_bps, n_ticks):\n"
        "    total = q_bytes + delay_s\n"
        "    return total\n")})
    assert "src/unit-suffix" in _rules(findings)
    ok, _ = lint_sources({"fix/u2.py": (
        "def f(q_bytes, extra_bytes, delay_s, rate_bps):\n"
        "    total = q_bytes + extra_bytes\n"
        "    secs = q_bytes / rate_bps + delay_s   # divide converts\n"
        "    return total, secs\n")})
    assert "src/unit-suffix" not in _rules(ok)


def test_indirect_scan_body_via_partial_and_alias():
    """Reachability follows `partial(...)` bindings and function-valued
    reassignments (the engine's tick_fn pattern)."""
    src = (
        "import jax\n"
        "import numpy as np\n"
        "from functools import partial\n"
        "def helper(c):\n"
        "    return np.cos(c)\n"
        "def tick(scale, c, x):\n"
        "    fn = helper\n"
        "    return fn(c) * scale, None\n"
        "def run(xs):\n"
        "    body = partial(tick, 2.0)\n"
        "    return jax.lax.scan(body, 0.0, xs)\n")
    findings, facts = lint_sources({"fix/ind.py": src})
    assert "src/np-in-scan" in _rules(findings)
    assert facts["scan_reachable"] >= 2      # tick and helper


# ---------------------------------------------------------------------------
# Counters + end-to-end runner
# ---------------------------------------------------------------------------

def test_counters_watch_counts_traces():
    cfg = _cfg(seed=101, sim_time=0.32)      # unique shape-free signature
    sweep = engine.make_sweep(cfg)
    with counters.watch() as w:
        engine.trace_sweep(cfg, sweep)
    first = w.traces
    with counters.watch() as w2:
        engine.trace_sweep(cfg, sweep)       # cache hit: no new trace
    assert first <= 1
    assert w2.traces == 0
    assert isinstance(w2.fallbacks, int)


def test_analyze_plan_end_to_end():
    report = analyze_plan("vp", _variant_plan())
    assert report.ok()
    proof = report.proofs["vp"]
    assert proof["groups_predicted"] == 2
    assert proof["groups_traced"] <= 2       # warm process may cache-hit
    assert proof["f64_ops"] == 0
    assert proof["kernel_fallbacks"] == 0
    rendered = report.render(verbose=True)
    assert "PASS" in rendered and "PROOF" in rendered


def test_rule_catalog_is_complete():
    expected = {
        "ir/kernel-missing", "ir/kernel-fallback", "ir/kernel-unexpected",
        "ir/f64-promotion", "ir/host-callback", "ir/nested-control",
        "plan/group-split", "plan/avoidable-split", "plan/group-mismatch",
        "plan/retrace",
        "src/np-in-scan", "src/float-cast-traced", "src/branch-on-traced",
        "src/f64-literal", "src/unit-suffix", "src/stale-pragma",
        "kernel/dyn-not-smem", "kernel/dyn-written", "kernel/state-not-vmem",
        "kernel/block-misaligned", "kernel/grid-remainder",
        "kernel/operand-mismatch", "kernel/f64-in-body",
        "kernel/gather-scatter", "kernel/nested-control",
        "kernel/vmem-budget",
        "budget/drift", "budget/missing-baseline", "budget/stale-baseline",
        "budget/env-mismatch", "budget/unknown-dtype",
    }
    assert set(RULES) == expected
    for r in RULES.values():
        assert r.summary and r.rationale


def test_severity_profiles():
    from repro.analysis import severity_for

    # ci promotes baseline-hygiene warnings; bench = declared defaults;
    # notebook demotes errors to advisory warnings unless overridden
    assert severity_for("src/stale-pragma") == "warning"
    assert severity_for("src/stale-pragma", "ci") == "error"
    assert severity_for("budget/missing-baseline", "ci") == "error"
    assert severity_for("budget/drift", "bench") == "error"
    assert severity_for("budget/drift", "notebook") == "warning"
    assert severity_for("kernel/dyn-not-smem", "ci") == "error"


def test_report_profile_gates_ok():
    from repro.analysis import AnalysisReport, make_finding

    f = make_finding("src/stale-pragma", "x.py:1", "stale")
    ci = AnalysisReport(profile="ci")
    ci.extend([f])
    assert not ci.ok() and ci.errors()
    nb = AnalysisReport(profile="notebook")
    nb.extend([f])
    assert nb.ok() and not nb.errors()
    js = ci.to_json()
    assert js["profile"] == "ci" and js["findings"][0]["severity"] == "error"
