"""Deterministic stand-in for `hypothesis` when it isn't installed.

The tier-1 suite must always collect (hypothesis is an optional test extra,
`pip install -e .[test]`).  When the real library is missing, `given` runs
the decorated test over a small deterministic grid of each strategy's range
(bounds, midpoints, and a golden-ratio interior point) instead of random
examples — far weaker than real property testing, but it keeps the
properties exercised on every run with zero extra dependencies.
"""
from __future__ import annotations

import itertools


class _Floats:
    def __init__(self, min_value, max_value):
        self.lo, self.hi = float(min_value), float(max_value)

    def samples(self):
        span = self.hi - self.lo
        pts = [self.lo, self.lo + 0.25 * span, self.lo + 0.5 * span,
               self.lo + 0.618 * span, self.hi]
        return sorted(set(pts))


class _Integers:
    def __init__(self, min_value, max_value):
        self.lo, self.hi = int(min_value), int(max_value)

    def samples(self):
        pts = {self.lo, self.hi, (self.lo + self.hi) // 2,
               self.lo + (self.hi - self.lo) // 3}
        return sorted(pts)


class strategies:
    floats = _Floats
    integers = _Integers


def given(**named_strategies):
    names = list(named_strategies)
    combos = list(itertools.product(
        *[named_strategies[n].samples() for n in names]))

    def deco(fn):
        # deliberately NOT functools.wraps: pytest must see a zero-argument
        # signature, not the strategy parameters (they are not fixtures)
        def wrapper():
            for combo in combos:
                fn(**dict(zip(names, combo)))
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


def settings(**_kwargs):
    def deco(fn):
        return fn
    return deco
