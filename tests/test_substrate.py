"""Substrate tests: checkpointing (atomic, resumable, re-shardable),
optimizer, gradient compression, data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, synthetic_batch
from repro.optim import (
    AdamWConfig,
    CompressionConfig,
    adamw_init,
    adamw_update,
    compress_gradients,
    init_error_feedback,
)
from repro.train import TrainHyper, init_train_state, make_train_step


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones((4,))}}
    mgr.save(10, tree, blocking=True)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = mgr.restore(like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    tree = {"x": jnp.ones((3,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=True)
    assert mgr.latest_step() == 4
    dirs = sorted(os.listdir(tmp_path))
    assert "step_1" not in dirs and "step_2" not in dirs
    assert "step_3" in dirs and "step_4" in dirs


def test_checkpoint_atomicity_no_partial(tmp_path):
    """tmp dirs never count as checkpoints."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), "step_9.tmp"))
    assert mgr.latest_step() is None


def test_checkpoint_elastic_reshard(tmp_path):
    """Save under one 'mesh', restore with different shardings (here: CPU
    single-device shardings as stand-ins — the device_put path)."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree, blocking=True)
    dev = jax.devices()[0]
    sh = {"w": jax.sharding.SingleDeviceSharding(dev)}
    out = mgr.restore(jax.tree.map(jnp.zeros_like, tree), shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


def test_train_resume_identical(tmp_path):
    """Crash/restart: resumed training state equals the saved one."""
    cfg = get_config("olmo-1b").scaled_down()
    hyper = TrainHyper(warmup=1)
    state = init_train_state(cfg, hyper, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, hyper))
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=2)
    for i in range(3):
        state, _ = step(state, synthetic_batch(dc, i))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, state, blocking=True)
    like = init_train_state(cfg, hyper, jax.random.PRNGKey(0))
    restored = mgr.restore(like)
    assert int(restored.step) == 3
    state, m1 = step(state, synthetic_batch(dc, 3))
    restored, m2 = step(restored, synthetic_batch(dc, 3))
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-6


def test_adamw_decreases_loss_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(cfg, params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, opt, params, grads)
    assert float(jnp.abs(params["w"]).max()) < 0.2


@pytest.mark.parametrize("scheme", ["topk", "int8"])
def test_compression_error_feedback_preserves_signal(scheme):
    """Accumulated (sent + residual) equals accumulated raw gradients."""
    cfg = CompressionConfig(scheme=scheme, topk_frac=0.25)
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .normal(size=(64,)).astype(np.float32))}
    resid = init_error_feedback(g)
    total_sent = jnp.zeros((64,))
    for _ in range(5):
        sent, resid = compress_gradients(cfg, g, resid)
        total_sent = total_sent + sent["w"]
    recovered = total_sent + resid["w"]
    np.testing.assert_allclose(np.asarray(recovered),
                               np.asarray(5 * g["w"]), rtol=1e-4, atol=1e-4)


def test_data_pipeline_deterministic_and_host_sharded():
    dc0 = DataConfig(vocab=100, seq_len=32, global_batch=8, host_id=0,
                     n_hosts=2)
    dc1 = DataConfig(vocab=100, seq_len=32, global_batch=8, host_id=1,
                     n_hosts=2)
    a = synthetic_batch(dc0, 7)["tokens"]
    b = synthetic_batch(dc0, 7)["tokens"]
    c = synthetic_batch(dc1, 7)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (4, 32)                        # host shard
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_quickstart_learns():
    """End-to-end: a tiny model's loss drops on the synthetic stream."""
    from repro.launch.train import train
    out = train("olmo-1b", steps=60, seq_len=48, batch=8, log_every=1000)
    assert out["last_loss"] < out["first_loss"] - 0.1, out
