"""Unit + property tests for the MLTCP core (protocol invariants)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ModuleNotFoundError:  # optional test extra; fall back to a fixed grid
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import (
    Algo,
    CCParams,
    Feedback,
    IterDetectParams,
    MLTCPConfig,
    Variant,
    cc_tick,
    init_state,
    make_fn,
    paper_functions,
    run_on_trace,
)
from repro.core.aggressiveness import is_srpt_reinforcing


# ---------------------------------------------------------------------------
# aggressiveness functions (paper §3.3 requirements)
# ---------------------------------------------------------------------------

def test_paper_functions_shapes():
    fns = paper_functions()
    xs = jnp.linspace(0, 1, 101)
    for name in ("F1", "F2", "F3", "F4"):
        assert is_srpt_reinforcing(fns[name]), name      # increasing
    for name in ("F5", "F6"):
        assert not is_srpt_reinforcing(fns[name]), name  # decreasing
    # all six share the range [0.25, 2] on [0, 1] (paper §4.8)
    for name, fn in fns.items():
        ys = np.asarray(fn(xs))
        assert ys.min() >= 0.24 and ys.max() <= 2.01, (name, ys.min(), ys.max())


@given(slope=st.floats(0.0, 4.0), intercept=st.floats(0.01, 2.0))
@settings(max_examples=50, deadline=None)
def test_linear_f_requirements(slope, intercept):
    f = make_fn("linear", slope, intercept)
    assert is_srpt_reinforcing(f)
    xs = jnp.linspace(0, 1, 33)
    assert bool(jnp.all(f(xs) > 0))      # aggressiveness must stay positive


# ---------------------------------------------------------------------------
# Algorithm 1 — iteration-boundary detection
# ---------------------------------------------------------------------------

def _trace_for_iterations(n_iters, comm_ticks, gap_ticks, dt=1e-4):
    """Synthetic ack trace: bursts of acks separated by silent gaps."""
    times, counts = [], []
    t = 0.0
    for _ in range(n_iters):
        for _ in range(comm_ticks):
            times.append(t)
            counts.append(10.0)
            t += dt
        t += gap_ticks * dt      # compute-phase silence
        times.append(t)          # first ack of next burst
        counts.append(10.0)
        t += dt
    return jnp.asarray(times), jnp.asarray(counts)


def test_algorithm1_detects_boundaries():
    n_iters = 8
    times, counts = _trace_for_iterations(n_iters, comm_ticks=50,
                                          gap_ticks=200)
    params = IterDetectParams(total_bytes=jnp.asarray([1e6]),
                              init_comm_gap=jnp.asarray(1e-3))
    final = run_on_trace(times, counts, params)
    # one boundary per gap (first ack after silence), +- the warmup one
    assert abs(int(final.n_boundaries[0]) - n_iters) <= 1
    # iter_gap EWMA converged near the true gap (200 * 1e-4 = 20 ms)
    assert 5e-3 < float(final.iter_gap[0]) < 40e-3


@given(gap_ticks=st.integers(100, 2000), comm_ticks=st.integers(20, 200))
@settings(max_examples=15, deadline=None)
def test_algorithm1_no_false_positives_within_comm(gap_ticks, comm_ticks):
    """Within a comm burst (uniform ack cadence) no boundaries fire after
    the initial one."""
    times, counts = _trace_for_iterations(4, comm_ticks, gap_ticks)
    params = IterDetectParams(total_bytes=jnp.asarray([1e6]),
                              init_comm_gap=jnp.asarray(1e-3))
    final = run_on_trace(times, counts, params)
    assert int(final.n_boundaries[0]) <= 5   # 4 gaps + possible warmup


def test_bytes_ratio_bounded():
    params = IterDetectParams(total_bytes=jnp.asarray([1e4]),
                              init_comm_gap=jnp.asarray(1.0))
    times = jnp.arange(100, dtype=jnp.float32) * 1e-4
    counts = jnp.full((100,), 100.0)  # sends far more than total_bytes
    final = run_on_trace(times, counts, params)
    assert 0.0 <= float(final.bytes_ratio[0]) <= 1.0


# ---------------------------------------------------------------------------
# congestion-control invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", [Algo.RENO, Algo.CUBIC, Algo.DCQCN])
@pytest.mark.parametrize("variant", [Variant.OFF, Variant.WI, Variant.MD])
def test_cc_state_stays_positive_and_bounded(algo, variant):
    cfg = MLTCPConfig(cc=CCParams(algo=int(algo), variant=int(variant)))
    n = 16
    st = init_state(n, cfg)
    total = jnp.full((n,), 1e7)
    rng = np.random.default_rng(0)
    for i in range(200):
        fb = Feedback(
            num_acks=jnp.asarray(rng.uniform(0, 30, n) *
                                 (rng.uniform(size=n) < 0.8), jnp.float32),
            loss=jnp.asarray(rng.uniform(size=n) < 0.05),
            cnp=jnp.asarray(rng.uniform(size=n) < 0.1),
            now=jnp.asarray(i * 2e-5, jnp.float32))
        st, rate = cc_tick(cfg, st, fb, total)
        assert bool(jnp.all(st.cc.cwnd >= cfg.cc.min_cwnd))
        assert bool(jnp.all(rate > 0))
        assert bool(jnp.all(st.cc.rate_cur <= cfg.cc.line_rate + 1))
        assert bool(jnp.all((st.cc.alpha >= 0) & (st.cc.alpha <= 1)))
        assert bool(jnp.all(jnp.isfinite(st.cc.cwnd)))


def test_md_never_increases_window():
    """A decrease step must never raise cwnd, even with F > 1 (MD clips)."""
    cfg = MLTCPConfig(cc=CCParams(algo=int(Algo.RENO),
                                  variant=int(Variant.MD)),
                      slope=1.0, intercept=1.0)   # F in [1, 2]
    st = init_state(4, cfg)
    st = st._replace(cc=st.cc._replace(cwnd=jnp.full((4,), 100.0)),
                     det=st.det._replace(bytes_ratio=jnp.asarray(
                         [0.0, 0.5, 0.9, 1.0])))
    fb = Feedback(num_acks=jnp.zeros(4), loss=jnp.ones(4, bool),
                  cnp=jnp.zeros(4, bool), now=jnp.asarray(1.0))
    st2, _ = cc_tick(cfg, st, fb, jnp.full((4,), 1e6))
    assert bool(jnp.all(st2.cc.cwnd <= 100.0))


def test_off_variant_ignores_bytes_ratio():
    cfg = MLTCPConfig(cc=CCParams(algo=int(Algo.RENO),
                                  variant=int(Variant.OFF)))
    st = init_state(2, cfg)
    st = st._replace(
        cc=st.cc._replace(cwnd=jnp.asarray([50.0, 50.0]),
                          ssthresh=jnp.asarray([1.0, 1.0])),
        det=st.det._replace(bytes_ratio=jnp.asarray([0.0, 1.0]),
                            prev_ack_tstamp=jnp.asarray([0.999, 0.999]),
                            iter_gap=jnp.asarray([10.0, 10.0])))
    fb = Feedback(num_acks=jnp.asarray([10.0, 10.0]),
                  loss=jnp.zeros(2, bool), cnp=jnp.zeros(2, bool),
                  now=jnp.asarray(1.0))
    st2, _ = cc_tick(cfg, st, fb, jnp.full((2,), 1e6))
    # same acks, different bytes_ratio -> identical growth when OFF
    assert float(st2.cc.cwnd[0]) == float(st2.cc.cwnd[1])
