"""Pallas kernel validation (interpret mode) against the pure-jnp oracles.

Per the brief: sweep shapes/dtypes per kernel and assert_allclose vs ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Algo,
    CCParams,
    Feedback,
    MLTCPConfig,
    Variant,
    cc_tick,
    init_state,
)
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (b, t, s, h, kv, dh, causal, window, softcap, dtype)
    (2, 128, 128, 4, 4, 64, True, 0, None, jnp.float32),
    (1, 256, 256, 4, 2, 64, True, 0, None, jnp.float32),
    (2, 128, 128, 4, 1, 32, True, 0, None, jnp.float32),     # MQA + pad dh
    (1, 256, 256, 2, 2, 128, True, 64, None, jnp.float32),   # sliding window
    (1, 128, 128, 2, 2, 64, True, 0, 50.0, jnp.float32),     # softcap
    (2, 128, 128, 4, 4, 64, False, 0, None, jnp.float32),    # bidirectional
    (1, 192, 192, 2, 2, 64, True, 0, None, jnp.float32),     # non-pow2 T pad
    (2, 128, 128, 4, 4, 64, True, 0, None, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    b, t, s, h, kv, dh, causal, window, softcap, dtype = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, t, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, dh), dtype)
    out = ops.flash_attention(q, k, v, causal, window, softcap)
    want = ref.ref_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_grad_matches_ref():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))

    def loss_kernel(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, True, 0, None) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.ref_attention(q, k, v) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

RGLRU_CASES = [
    (2, 64, 128, jnp.float32),
    (1, 128, 256, jnp.float32),
    (3, 33, 130, jnp.float32),     # ragged D -> pad
    (2, 64, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("case", RGLRU_CASES)
def test_rg_lru_matches_ref(case):
    b, t, d, dtype = case
    ks = jax.random.split(KEY, 2)
    a = jax.random.uniform(ks[0], (b, t, d), dtype, 0.2, 0.99)
    x = jax.random.normal(ks[1], (b, t, d), dtype)
    out = ops.rg_lru(a, x)
    want = ref.ref_rg_lru(a, x)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_rg_lru_grad_matches_ref():
    ks = jax.random.split(KEY, 2)
    a = jax.random.uniform(ks[0], (2, 32, 128), jnp.float32, 0.2, 0.99)
    x = jax.random.normal(ks[1], (2, 32, 128))
    gk = jax.grad(lambda a, x: jnp.sum(ops.rg_lru(a, x) ** 2),
                  argnums=(0, 1))(a, x)
    gr = jax.grad(lambda a, x: jnp.sum(ref.ref_rg_lru(a, x) ** 2),
                  argnums=(0, 1))(a, x)
    for g1, g2 in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused protocol tick
# ---------------------------------------------------------------------------

def _random_protocol_state(n, cfg, key):
    st = init_state(n, cfg)
    ks = jax.random.split(key, 12)
    det = st.det._replace(
        bytes_sent=jax.random.uniform(ks[0], (n,)) * 1e8,
        bytes_ratio=jax.random.uniform(ks[1], (n,)),
        prev_ack_tstamp=jax.random.uniform(ks[2], (n,)) * 0.01,
        iter_gap=jax.random.uniform(ks[3], (n,), minval=1e-3, maxval=0.05),
        max_gap=jax.random.uniform(ks[4], (n,), minval=1e-3, maxval=0.05),
    )
    cc = st.cc._replace(
        cwnd=jax.random.uniform(ks[5], (n,), minval=1.0, maxval=500.0),
        ssthresh=jax.random.uniform(ks[6], (n,), minval=10.0, maxval=1e4),
        cooldown=jax.random.uniform(ks[7], (n,)) * 2e-4,
        w_max=jax.random.uniform(ks[8], (n,), minval=1.0, maxval=500.0),
        epoch_start=jax.random.uniform(ks[9], (n,)) * 0.01,
        rate_cur=jax.random.uniform(ks[10], (n,), minval=1e6, maxval=6e9),
        rate_target=jax.random.uniform(ks[11], (n,), minval=1e6, maxval=6e9),
        alpha=jax.random.uniform(ks[0], (n,)),
        t_last_cnp=jax.random.uniform(ks[1], (n,)) * 0.01,
        t_last_inc=jax.random.uniform(ks[2], (n,)) * 0.01,
        t_last_alpha=jax.random.uniform(ks[3], (n,)) * 0.01,
        inc_stage=jax.random.randint(ks[4], (n,), 0, 10),
    )
    return st._replace(det=det, cc=cc)


PROTO_CASES = [
    (Algo.RENO, Variant.WI, 1.75, 0.25),
    (Algo.RENO, Variant.MD, 1.0, 1.0),
    (Algo.RENO, Variant.OFF, 1.75, 0.25),
    (Algo.CUBIC, Variant.WI, 1.0, 0.5),
    (Algo.CUBIC, Variant.MD, 0.8, 0.8),
    (Algo.DCQCN, Variant.WI, 1.067, 0.267),
    (Algo.DCQCN, Variant.MD, 1.067, 0.267),
    (Algo.DCQCN, Variant.BOTH, 1.067, 0.267),
]


@pytest.mark.parametrize("case", PROTO_CASES)
@pytest.mark.parametrize("n", [7, 64, 300])
def test_mltcp_tick_kernel_matches_core(case, n):
    algo, variant, slope, intercept = case
    cfg = MLTCPConfig(cc=CCParams(algo=int(algo), variant=int(variant)),
                      slope=slope, intercept=intercept)
    key = jax.random.PRNGKey(n)
    st = _random_protocol_state(n, cfg, key)
    ks = jax.random.split(key, 4)
    fb = Feedback(
        num_acks=jnp.where(jax.random.uniform(ks[0], (n,)) < 0.7,
                           jax.random.uniform(ks[1], (n,)) * 40.0, 0.0),
        loss=jax.random.uniform(ks[2], (n,)) < 0.2,
        cnp=jax.random.uniform(ks[3], (n,)) < 0.3,
        now=jnp.asarray(0.0123),
    )
    total = jnp.full((n,), 1e8)
    f2j = jnp.arange(n) % 3

    want_st, want_rate = cc_tick(cfg, st, fb, total, flow_to_job=f2j,
                                 n_jobs=3)
    got_st, got_rate = ops.mltcp_cc_tick(cfg, st, fb, total, flow_to_job=f2j,
                                         n_jobs=3)
    np.testing.assert_allclose(np.asarray(got_rate), np.asarray(want_rate),
                               rtol=1e-6)
    for name in want_st.cc._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(got_st.cc, name)),
            np.asarray(getattr(want_st.cc, name)), rtol=1e-6,
            err_msg=f"cc.{name}")
    for name in want_st.det._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(got_st.det, name)),
            np.asarray(getattr(want_st.det, name)), rtol=1e-6,
            err_msg=f"det.{name}")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_n_boundaries_match_over_fuzzed_sequences(seed):
    """Fuzz Algorithm 1's boundary counter across many ticks: the kernel
    wrapper's out-of-kernel counter (via `iteration.boundary_mask`) must
    track the jnp oracle exactly — one source of truth, no drift."""
    n, n_ticks, dt = 33, 120, 2e-5
    cfg = MLTCPConfig(cc=CCParams(algo=int(Algo.RENO),
                                  variant=int(Variant.WI), tick_dt=dt),
                      slope=1.75, intercept=0.25, init_comm_gap=3 * dt)
    st_ref = init_state(n, cfg)
    st_ker = init_state(n, cfg)
    total = jnp.full((n,), 2e6)
    f2j = jnp.arange(n) % 4
    rng = np.random.default_rng(seed)
    for i in range(n_ticks):
        # bursty on/off ack pattern so gaps straddle g * iter_gap
        burst = rng.uniform(size=n) < (0.9 if (i // 10) % 2 == 0 else 0.05)
        fb = Feedback(
            num_acks=jnp.asarray(burst * rng.uniform(1, 20, n), jnp.float32),
            loss=jnp.asarray(rng.uniform(size=n) < 0.03),
            cnp=jnp.zeros((n,), bool),
            now=jnp.asarray(i * dt, jnp.float32))
        st_ref, _ = cc_tick(cfg, st_ref, fb, total, flow_to_job=f2j, n_jobs=4)
        st_ker, _ = ops.mltcp_cc_tick(cfg, st_ker, fb, total,
                                      flow_to_job=f2j, n_jobs=4)
        np.testing.assert_array_equal(
            np.asarray(st_ker.det.n_boundaries),
            np.asarray(st_ref.det.n_boundaries),
            err_msg=f"n_boundaries drift at tick {i}")
    assert int(np.asarray(st_ref.det.n_boundaries).max()) > 0
