"""Pallas kernel validation (interpret mode) against the pure-jnp oracles.

Per the brief: sweep shapes/dtypes per kernel and assert_allclose vs ref.py.
The CC-tick kernel is additionally exercised with *traced* DynamicParams
and under vmap (the sweep-engine shapes), where the operand-carried
protocol scalars must keep it fused — FALLBACK_COUNT pins that no case
silently routes through the jnp oracle.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Algo,
    CCParams,
    DynamicParams,
    Feedback,
    MLTCPConfig,
    Variant,
    cc_tick,
    init_state,
)
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (b, t, s, h, kv, dh, causal, window, softcap, dtype)
    (2, 128, 128, 4, 4, 64, True, 0, None, jnp.float32),
    (1, 256, 256, 4, 2, 64, True, 0, None, jnp.float32),
    (2, 128, 128, 4, 1, 32, True, 0, None, jnp.float32),     # MQA + pad dh
    (1, 256, 256, 2, 2, 128, True, 64, None, jnp.float32),   # sliding window
    (1, 128, 128, 2, 2, 64, True, 0, 50.0, jnp.float32),     # softcap
    (2, 128, 128, 4, 4, 64, False, 0, None, jnp.float32),    # bidirectional
    (1, 192, 192, 2, 2, 64, True, 0, None, jnp.float32),     # non-pow2 T pad
    (2, 128, 128, 4, 4, 64, True, 0, None, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    b, t, s, h, kv, dh, causal, window, softcap, dtype = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, t, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, dh), dtype)
    out = ops.flash_attention(q, k, v, causal, window, softcap)
    want = ref.ref_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_grad_matches_ref():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))

    def loss_kernel(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, True, 0, None) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.ref_attention(q, k, v) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

RGLRU_CASES = [
    (2, 64, 128, jnp.float32),
    (1, 128, 256, jnp.float32),
    (3, 33, 130, jnp.float32),     # ragged D -> pad
    (2, 64, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("case", RGLRU_CASES)
def test_rg_lru_matches_ref(case):
    b, t, d, dtype = case
    ks = jax.random.split(KEY, 2)
    a = jax.random.uniform(ks[0], (b, t, d), dtype, 0.2, 0.99)
    x = jax.random.normal(ks[1], (b, t, d), dtype)
    out = ops.rg_lru(a, x)
    want = ref.ref_rg_lru(a, x)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_rg_lru_grad_matches_ref():
    ks = jax.random.split(KEY, 2)
    a = jax.random.uniform(ks[0], (2, 32, 128), jnp.float32, 0.2, 0.99)
    x = jax.random.normal(ks[1], (2, 32, 128))
    gk = jax.grad(lambda a, x: jnp.sum(ops.rg_lru(a, x) ** 2),
                  argnums=(0, 1))(a, x)
    gr = jax.grad(lambda a, x: jnp.sum(ref.ref_rg_lru(a, x) ** 2),
                  argnums=(0, 1))(a, x)
    for g1, g2 in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused protocol tick
# ---------------------------------------------------------------------------

def _random_protocol_state(n, cfg, key):
    st = init_state(n, cfg)
    ks = jax.random.split(key, 12)
    det = st.det._replace(
        bytes_sent=jax.random.uniform(ks[0], (n,)) * 1e8,
        bytes_ratio=jax.random.uniform(ks[1], (n,)),
        prev_ack_tstamp=jax.random.uniform(ks[2], (n,)) * 0.01,
        iter_gap=jax.random.uniform(ks[3], (n,), minval=1e-3, maxval=0.05),
        max_gap=jax.random.uniform(ks[4], (n,), minval=1e-3, maxval=0.05),
    )
    cc = st.cc._replace(
        cwnd=jax.random.uniform(ks[5], (n,), minval=1.0, maxval=500.0),
        ssthresh=jax.random.uniform(ks[6], (n,), minval=10.0, maxval=1e4),
        cooldown=jax.random.uniform(ks[7], (n,)) * 2e-4,
        w_max=jax.random.uniform(ks[8], (n,), minval=1.0, maxval=500.0),
        epoch_start=jax.random.uniform(ks[9], (n,)) * 0.01,
        rate_cur=jax.random.uniform(ks[10], (n,), minval=1e6, maxval=6e9),
        rate_target=jax.random.uniform(ks[11], (n,), minval=1e6, maxval=6e9),
        alpha=jax.random.uniform(ks[0], (n,)),
        t_last_cnp=jax.random.uniform(ks[1], (n,)) * 0.01,
        t_last_inc=jax.random.uniform(ks[2], (n,)) * 0.01,
        t_last_alpha=jax.random.uniform(ks[3], (n,)) * 0.01,
        inc_stage=jax.random.randint(ks[4], (n,), 0, 10),
    )
    return st._replace(det=det, cc=cc)


PROTO_CASES = [
    (Algo.RENO, Variant.WI, 1.75, 0.25),
    (Algo.RENO, Variant.MD, 1.0, 1.0),
    (Algo.RENO, Variant.OFF, 1.75, 0.25),
    (Algo.CUBIC, Variant.WI, 1.0, 0.5),
    (Algo.CUBIC, Variant.MD, 0.8, 0.8),
    (Algo.DCQCN, Variant.WI, 1.067, 0.267),
    (Algo.DCQCN, Variant.MD, 1.067, 0.267),
    (Algo.DCQCN, Variant.BOTH, 1.067, 0.267),
]


@pytest.mark.parametrize("case", PROTO_CASES)
@pytest.mark.parametrize("n", [7, 64, 300])
def test_mltcp_tick_kernel_matches_core(case, n):
    algo, variant, slope, intercept = case
    cfg = MLTCPConfig(cc=CCParams(algo=int(algo), variant=int(variant)),
                      slope=slope, intercept=intercept)
    key = jax.random.PRNGKey(n)
    st = _random_protocol_state(n, cfg, key)
    ks = jax.random.split(key, 4)
    fb = Feedback(
        num_acks=jnp.where(jax.random.uniform(ks[0], (n,)) < 0.7,
                           jax.random.uniform(ks[1], (n,)) * 40.0, 0.0),
        loss=jax.random.uniform(ks[2], (n,)) < 0.2,
        cnp=jax.random.uniform(ks[3], (n,)) < 0.3,
        now=jnp.asarray(0.0123),
    )
    total = jnp.full((n,), 1e8)
    f2j = jnp.arange(n) % 3

    want_st, want_rate = cc_tick(cfg, st, fb, total, flow_to_job=f2j,
                                 n_jobs=3)
    got_st, got_rate = ops.mltcp_cc_tick(cfg, st, fb, total, flow_to_job=f2j,
                                         n_jobs=3)
    np.testing.assert_allclose(np.asarray(got_rate), np.asarray(want_rate),
                               rtol=1e-6)
    for name in want_st.cc._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(got_st.cc, name)),
            np.asarray(getattr(want_st.cc, name)), rtol=1e-6,
            err_msg=f"cc.{name}")
    for name in want_st.det._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(got_st.det, name)),
            np.asarray(getattr(want_st.det, name)), rtol=1e-6,
            err_msg=f"det.{name}")


def _random_feedback(n, key, now=0.0123):
    ks = jax.random.split(key, 4)
    return Feedback(
        num_acks=jnp.where(jax.random.uniform(ks[0], (n,)) < 0.7,
                           jax.random.uniform(ks[1], (n,)) * 40.0, 0.0),
        loss=jax.random.uniform(ks[2], (n,)) < 0.2,
        cnp=jax.random.uniform(ks[3], (n,)) < 0.3,
        now=jnp.asarray(now),
    )


def _assert_states_equal(got, want, exact=False):
    assert_fn = (np.testing.assert_array_equal if exact else
                 lambda a, b, err_msg: np.testing.assert_allclose(
                     a, b, rtol=1e-6, err_msg=err_msg))
    for grp in ("cc", "det"):
        for name in getattr(want, grp)._fields:
            assert_fn(np.asarray(getattr(getattr(got, grp), name)),
                      np.asarray(getattr(getattr(want, grp), name)),
                      err_msg=f"{grp}.{name}")


@pytest.mark.parametrize("case", [(Algo.RENO, Variant.WI),
                                  (Algo.CUBIC, Variant.MD),
                                  (Algo.DCQCN, Variant.BOTH)])
def test_mltcp_tick_kernel_traced_dyn_stays_fused(case):
    """Traced DynamicParams (the sweep axis) run through the fused kernel —
    operand-carried scalars, no oracle fallback, bit-equal to core."""
    algo, variant = case
    n = 70
    cfg = MLTCPConfig(cc=CCParams(algo=int(algo), variant=int(variant)))
    st = _random_protocol_state(n, cfg, jax.random.PRNGKey(5))
    fb = _random_feedback(n, jax.random.PRNGKey(6))
    total = jnp.full((n,), 1e8)
    f2j = jnp.arange(n) % 4

    def run(tick_fn, dyn_vals):
        dyn = DynamicParams(*dyn_vals)
        st2, rate = tick_fn(cfg, st, fb, total, flow_to_job=f2j, n_jobs=4,
                            dyn=dyn)
        return st2, rate

    dyn_vals = tuple(jnp.asarray(v, jnp.float32)
                     for v in (1.3, 0.4, 0.8, 0.45, 2e-3))
    before = ops.FALLBACK_COUNT
    got_st, got_rate = jax.jit(lambda dv: run(ops.mltcp_cc_tick, dv))(dyn_vals)
    want_st, want_rate = jax.jit(lambda dv: run(cc_tick, dv))(dyn_vals)
    assert ops.FALLBACK_COUNT == before
    _assert_states_equal(got_st, want_st, exact=True)
    np.testing.assert_array_equal(np.asarray(got_rate), np.asarray(want_rate))


def test_mltcp_tick_kernel_vmaps_over_dyn():
    """A batched DynamicParams axis (K sweep points) vmaps over the kernel
    call — one fused program, K results matching core point-for-point."""
    n, k = 40, 5
    cfg = MLTCPConfig(cc=CCParams(algo=int(Algo.RENO),
                                  variant=int(Variant.WI)))
    st = _random_protocol_state(n, cfg, jax.random.PRNGKey(8))
    fb = _random_feedback(n, jax.random.PRNGKey(9))
    total = jnp.full((n,), 1e8)
    f2j = jnp.arange(n) % 3
    slopes = jnp.linspace(0.5, 2.5, k, dtype=jnp.float32)
    base = DynamicParams.from_config(cfg)
    dyns = DynamicParams(slope=slopes,
                         intercept=jnp.broadcast_to(base.intercept, (k,)),
                         g=jnp.broadcast_to(base.g, (k,)),
                         gamma=jnp.broadcast_to(base.gamma, (k,)),
                         init_comm_gap=jnp.broadcast_to(base.init_comm_gap,
                                                        (k,)))

    def one(tick_fn, dyn):
        st2, rate = tick_fn(cfg, st, fb, total, flow_to_job=f2j, n_jobs=3,
                            dyn=dyn)
        return st2.cc.cwnd, st2.det.bytes_ratio, rate

    before = ops.FALLBACK_COUNT
    got = jax.jit(jax.vmap(lambda d: one(ops.mltcp_cc_tick, d)))(dyns)
    want = jax.jit(jax.vmap(lambda d: one(cc_tick, d)))(dyns)
    assert ops.FALLBACK_COUNT == before
    for g, w in zip(got, want):
        assert g.shape[0] == k
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # the sweep axis must actually vary the outcome
    assert np.unique(np.asarray(got[0]), axis=0).shape[0] > 1


def test_mltcp_tick_kernel_static_factors():
    """The Static [67] per-flow factors ride into the kernel as an operand
    (they used to force an unconditional oracle fallback) — and since they
    replace F(score) entirely, even a non-linear f_spec stays fused."""
    n = 33
    cfg = MLTCPConfig(cc=CCParams(algo=int(Algo.RENO),
                                  variant=int(Variant.WI)),
                      f_spec="F3")
    st = _random_protocol_state(n, cfg, jax.random.PRNGKey(11))
    fb = _random_feedback(n, jax.random.PRNGKey(12))
    total = jnp.full((n,), 1e8)
    f2j = jnp.arange(n) % 3
    factors = jnp.asarray(0.5 + 1.5 * (jnp.arange(n) % 3) / 2.0, jnp.float32)

    before = ops.FALLBACK_COUNT
    got_st, got_rate = ops.mltcp_cc_tick(cfg, st, fb, total, flow_to_job=f2j,
                                         n_jobs=3, static_factors=factors)
    assert ops.FALLBACK_COUNT == before
    want_st, want_rate = cc_tick(cfg, st, fb, total, flow_to_job=f2j,
                                 n_jobs=3, static_factors=factors)
    _assert_states_equal(got_st, want_st)
    np.testing.assert_allclose(np.asarray(got_rate), np.asarray(want_rate),
                               rtol=1e-6)


def test_mltcp_tick_fallback_is_loud():
    """Structural options outside the kernel's specialization fall back to
    the oracle — incrementing FALLBACK_COUNT and warning once."""
    n = 16
    cfg = MLTCPConfig(cc=CCParams(algo=int(Algo.RENO),
                                  variant=int(Variant.WI)),
                      favoritism="earliest_iter_start")
    st = _random_protocol_state(n, cfg, jax.random.PRNGKey(13))
    fb = _random_feedback(n, jax.random.PRNGKey(14))
    total = jnp.full((n,), 1e8)

    before = ops.FALLBACK_COUNT
    ops._FALLBACK_WARNED.discard("favoritism='earliest_iter_start'")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        got_st, _ = ops.mltcp_cc_tick(cfg, st, fb, total)
    assert ops.FALLBACK_COUNT == before + 1
    assert any("favoritism" in str(x.message) for x in w)
    # one-time: a second call with the same reason stays silent
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        ops.mltcp_cc_tick(cfg, st, fb, total)
    assert ops.FALLBACK_COUNT == before + 2
    assert not any("favoritism" in str(x.message) for x in w2)
    # and the fallback result is the oracle's
    want_st, _ = cc_tick(cfg, st, fb, total)
    _assert_states_equal(got_st, want_st)


def test_interpret_env_flag_parsing():
    """REPRO_INTERPRET controls ops.INTERPRET without a source edit."""
    assert ops._env_flag("REPRO_TEST_MISSING_FLAG", True) is True
    assert ops._env_flag("REPRO_TEST_MISSING_FLAG", False) is False
    import os
    for raw, want in [("0", False), ("false", False), ("no", False),
                      ("", True), ("  ", True),   # blank == unset -> default
                      ("1", True), ("true", True), ("TPU", True)]:
        os.environ["REPRO_TEST_FLAG"] = raw
        try:
            assert ops._env_flag("REPRO_TEST_FLAG", True) is want, raw
        finally:
            del os.environ["REPRO_TEST_FLAG"]


def test_interpret_per_call_override():
    """Every kernel wrapper takes a per-call interpret override (None =
    module default); interpret=True must behave exactly like the default
    on this CPU container."""
    n = 24
    cfg = MLTCPConfig(cc=CCParams(algo=int(Algo.RENO),
                                  variant=int(Variant.WI)))
    st = _random_protocol_state(n, cfg, jax.random.PRNGKey(15))
    fb = _random_feedback(n, jax.random.PRNGKey(16))
    total = jnp.full((n,), 1e8)
    a_st, a_rate = ops.mltcp_cc_tick(cfg, st, fb, total, interpret=True)
    b_st, b_rate = ops.mltcp_cc_tick(cfg, st, fb, total)
    _assert_states_equal(a_st, b_st, exact=True)
    np.testing.assert_array_equal(np.asarray(a_rate), np.asarray(b_rate))

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    np.testing.assert_array_equal(
        np.asarray(ops.flash_attention(q, k, v, True, 0, None, True)),
        np.asarray(ops.flash_attention(q, k, v)))
    a = jax.random.uniform(ks[0], (2, 32, 128), jnp.float32, 0.2, 0.99)
    x = jax.random.normal(ks[1], (2, 32, 128))
    np.testing.assert_array_equal(np.asarray(ops.rg_lru(a, x, True)),
                                  np.asarray(ops.rg_lru(a, x)))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_n_boundaries_match_over_fuzzed_sequences(seed):
    """Fuzz Algorithm 1's boundary counter across many ticks: the kernel
    wrapper's out-of-kernel counter (via `iteration.boundary_mask`) must
    track the jnp oracle exactly — one source of truth, no drift."""
    n, n_ticks, dt = 33, 120, 2e-5
    cfg = MLTCPConfig(cc=CCParams(algo=int(Algo.RENO),
                                  variant=int(Variant.WI), tick_dt=dt),
                      slope=1.75, intercept=0.25, init_comm_gap=3 * dt)
    st_ref = init_state(n, cfg)
    st_ker = init_state(n, cfg)
    total = jnp.full((n,), 2e6)
    f2j = jnp.arange(n) % 4
    rng = np.random.default_rng(seed)
    for i in range(n_ticks):
        # bursty on/off ack pattern so gaps straddle g * iter_gap
        burst = rng.uniform(size=n) < (0.9 if (i // 10) % 2 == 0 else 0.05)
        fb = Feedback(
            num_acks=jnp.asarray(burst * rng.uniform(1, 20, n), jnp.float32),
            loss=jnp.asarray(rng.uniform(size=n) < 0.03),
            cnp=jnp.zeros((n,), bool),
            now=jnp.asarray(i * dt, jnp.float32))
        st_ref, _ = cc_tick(cfg, st_ref, fb, total, flow_to_job=f2j, n_jobs=4)
        st_ker, _ = ops.mltcp_cc_tick(cfg, st_ker, fb, total,
                                      flow_to_job=f2j, n_jobs=4)
        np.testing.assert_array_equal(
            np.asarray(st_ker.det.n_boundaries),
            np.asarray(st_ref.det.n_boundaries),
            err_msg=f"n_boundaries drift at tick {i}")
    assert int(np.asarray(st_ref.det.n_boundaries).max()) > 0
