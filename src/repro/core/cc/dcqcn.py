"""DCQCN +/- MLTCP ("MLQCN", paper §3.4, Eqs. 12-15).

DCQCN is the rate-based CC used in lossless RoCE fabrics [Zhu et al. 2015].
Congestion Notification Packets (CNPs, derived from ECN marks) drive
multiplicative decrease; timers drive recovery and additive increase.

Rate increase (additive-increase stage):
    default:  target_rate += R_AI                            (Eq. 12)
    MLQCN-WI: target_rate += F(bytes_ratio) * R_AI           (Eq. 13)

Rate decrease (on CNP):
    default:  curr_rate = (1 - alpha/2) * curr_rate          (Eq. 14)
    MLQCN-MD: curr_rate = F(bytes_ratio)*(1 - alpha/2)*curr_rate  (Eq. 15)

alpha follows the DCQCN EWMA: on CNP  alpha <- (1-g)*alpha + g; it decays by
(1-g) on an ``alpha_timer`` when no CNP arrives.  Recovery uses the standard
staged scheme: the first ``fast_recovery_stages`` increase events halve the
gap to target_rate; later stages additively raise target_rate (Eq. 12/13)
before halving the gap.  Hyper-increase is omitted (the paper leaves DCQCN's
other stages untouched and its NICs cap at line rate anyway); rates clip to
[rate_min, line_rate].
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.cc.types import CCParams, Feedback, FlowCCState

Array = jnp.ndarray


def update(params: CCParams, state: FlowCCState, fb: Feedback,
           f_wi: Array, f_md: Array) -> FlowCCState:
    now = fb.now
    # NICs honor at most one CNP per cnp_interval per flow (rate limiter).
    cnp = fb.cnp & ((now - state.t_last_cnp) >= params.cnp_interval)

    # ---------- multiplicative decrease on CNP (Eq. 15) ----------
    alpha_on_cnp = (1.0 - params.dcqcn_g) * state.alpha + params.dcqcn_g
    # a decrease step must not increase the rate: clip F*(1-a/2) at 1.
    md_mult = jnp.minimum(f_md * (1.0 - state.alpha / 2.0), 1.0)
    rate_cut = jnp.clip(md_mult * state.rate_cur,
                        params.rate_min, params.line_rate)

    # ---------- alpha decay when quiet ----------
    alpha_timer_fired = (now - state.t_last_alpha) >= params.alpha_timer
    alpha_decayed = jnp.where(alpha_timer_fired,
                              (1.0 - params.dcqcn_g) * state.alpha, state.alpha)

    # ---------- staged rate increase ----------
    inc_timer_fired = (now - state.t_last_inc) >= params.inc_timer
    stage = state.inc_stage + inc_timer_fired.astype(jnp.int32)
    in_ai = stage > params.fast_recovery_stages
    # Eq. 13: additive increase on target rate, scaled by F in the AI stage.
    tgt_inc = jnp.where(inc_timer_fired & in_ai,
                        state.rate_target + f_wi * params.rate_ai,
                        state.rate_target)
    tgt_inc = jnp.minimum(tgt_inc, params.line_rate)
    # The increase *step* toward target is DCQCN's bisection recovery; MLTCP's
    # principle (Eq. 2: scale every increase step by F) applies here too —
    # under persistent CNPs flows rarely leave fast recovery, so scaling only
    # R_AI would leave no favoritism signal (hardware-adaptation note,
    # DESIGN.md §2). F=1 recovers the default algorithm exactly.
    step = jnp.minimum(f_wi, 2.0) * 0.5 * (tgt_inc - state.rate_cur)
    rate_inc = jnp.where(inc_timer_fired, state.rate_cur + step, state.rate_cur)

    # ---------- merge: CNP path wins ----------
    new_rate = jnp.where(cnp, rate_cut, rate_inc)
    new_target = jnp.where(cnp, state.rate_cur, tgt_inc)
    new_alpha = jnp.where(cnp, alpha_on_cnp, alpha_decayed)
    new_stage = jnp.where(cnp, jnp.zeros_like(stage), stage)
    new_t_inc = jnp.where(cnp | inc_timer_fired, now, state.t_last_inc)
    new_t_alpha = jnp.where(cnp | alpha_timer_fired, now, state.t_last_alpha)

    return state._replace(
        rate_cur=jnp.clip(new_rate, params.rate_min, params.line_rate),
        rate_target=jnp.clip(new_target, params.rate_min, params.line_rate),
        alpha=jnp.clip(new_alpha, 0.0, 1.0),
        inc_stage=new_stage,
        t_last_cnp=jnp.where(cnp, now, state.t_last_cnp),
        t_last_inc=new_t_inc,
        t_last_alpha=new_t_alpha,
    )
