"""Congestion-control algorithms augmented by MLTCP (paper §3.4).

Each algorithm is a pure function over a unified per-flow state
(`repro.core.mltcp.FlowCCState`), so that one vectorized update serves the
netsim engine, the Pallas fused kernel oracle, and standalone tests.
"""

from repro.core.cc import reno, cubic, dcqcn  # noqa: F401
