"""TCP CUBIC +/- MLTCP (paper §3.4, Eqs. 8-11).

Window growth:
    default:  cwnd = CUBIC(t)                                (Eq. 8)
    MLTCP-WI: cwnd = CUBIC(F(bytes_ratio) * t)               (Eq. 9)

where t is the time since the last multiplicative-decrease event and
CUBIC(t) = C*(t - K)^3 + w_max with K = cbrt(w_max * (1 - beta) / C).
A smaller F dilates time for the less-favored flow, so it climbs back toward
w_max more slowly — exactly the paper's mechanism.

Multiplicative decrease:
    default:  cwnd = beta * cwnd                             (Eq. 10)
    MLTCP-MD: cwnd = F(bytes_ratio) * beta * cwnd            (Eq. 11)

The paper scales ``bic_scale`` to make CUBIC responsive at testbed (~100 us)
RTTs; we expose the same knob as ``cubic_scale`` multiplying C.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.cc.types import CCParams, Feedback, FlowCCState

Array = jnp.ndarray


def _cubic_target(params: CCParams, w_max: Array, t: Array) -> Array:
    c = params.cubic_c * params.cubic_scale
    # (1-beta)/c folds to one python-float constant (no constant-divisor
    # division in the graph — keeps kernel/oracle programs bit-identical)
    k = jnp.cbrt(w_max * ((1.0 - params.cubic_beta) / c))
    return c * (t - k) ** 3 + w_max


def update(params: CCParams, state: FlowCCState, fb: Feedback,
           f_wi: Array, f_md: Array) -> FlowCCState:
    cwnd = state.cwnd

    # ---- growth toward the cubic target (on acks) ----
    t = jnp.maximum(fb.now - state.epoch_start, 0.0)
    target = _cubic_target(params, state.w_max, f_wi * t)       # Eq. 9
    # per-ack growth (cwnd += (target-cwnd)/cwnd per ack), vectorized over the
    # tick's ack batch; clipped to at most ~50% growth per tick for stability.
    grow = fb.num_acks * jnp.maximum(target - cwnd, 0.0) / jnp.maximum(cwnd, 1e-6)
    # slow start below ssthresh (untouched by MLTCP, §3.4), cubic above.
    in_ss = cwnd < state.ssthresh
    cwnd_inc = cwnd + jnp.where(in_ss, fb.num_acks,
                                jnp.minimum(grow, 0.5 * cwnd + 1.0))

    # ---- multiplicative decrease (once per RTT) ----
    can_cut = state.cooldown <= 0.0
    do_cut = fb.loss & can_cut
    # Eq. 11, with F*beta clipped at 1 (a decrease never increases cwnd).
    cwnd_cut = jnp.maximum(jnp.minimum(f_md * params.cubic_beta, 1.0) * cwnd,
                           params.min_cwnd)

    new_cwnd = jnp.where(do_cut, cwnd_cut, cwnd_inc)
    return state._replace(
        cwnd=new_cwnd,
        w_max=jnp.where(do_cut, cwnd, state.w_max),
        epoch_start=jnp.where(do_cut, fb.now, state.epoch_start),
        ssthresh=jnp.where(do_cut, jnp.maximum(cwnd_cut, 2.0), state.ssthresh),
        cooldown=jnp.where(do_cut, params.rtt,
                           jnp.maximum(state.cooldown - params.tick_dt, 0.0)),
    )
