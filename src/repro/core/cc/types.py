"""Shared types for the congestion-control layer.

One unified per-flow state struct carries the fields of all three algorithms
(Reno / CUBIC / DCQCN); a simulation instantiates exactly one algorithm
(matching the paper's testbed, where the whole fabric runs one CC variant),
so unused fields cost a few floats per flow and keep every update branch-free
and fully vectorized — the property that lets the netsim engine `lax.scan`
over millions of ticks and the Pallas kernel fuse the whole tick.
"""
from __future__ import annotations

import enum
from typing import NamedTuple

import jax.numpy as jnp

Array = jnp.ndarray


class Algo(enum.IntEnum):
    RENO = 0
    CUBIC = 1
    DCQCN = 2


class Variant(enum.IntEnum):
    """Where MLTCP's F scales the algorithm (paper §3.3 has two mechanisms)."""

    OFF = 0   # default congestion control (baseline)
    WI = 1    # scale the window/rate increase step        (Eqs. 5, 9, 13)
    MD = 2    # scale the multiplicative decrease step     (Eqs. 7, 11, 15)
    BOTH = 3  # both (paper: either alone suffices; kept for ablations)


class CCParams(NamedTuple):
    """Static parameters (python floats — baked into the jitted program)."""

    algo: int = int(Algo.RENO)
    variant: int = int(Variant.WI)
    mss: float = 1500.0                # bytes per packet (paper: MTU 1500)
    rtt: float = 100e-6                # base round-trip time (s)
    tick_dt: float = 20e-6             # simulator tick (s); used for timers
    min_cwnd: float = 1.0              # packets
    init_cwnd: float = 10.0            # packets
    init_ssthresh: float = 1e9
    # --- CUBIC ---
    cubic_c: float = 0.4               # standard CUBIC C (units: pkts/s^3)
    cubic_beta: float = 0.7            # standard CUBIC multiplicative decrease
    cubic_scale: float = 1e10          # paper §4.1 scales bic_scale by 1e10
                                       # so CUBIC reacts at ~100 us RTTs
    # --- Reno ---
    reno_beta: float = 0.5             # Eq. 6
    # --- DCQCN ---
    line_rate: float = 50e9 / 8        # bytes/s (50 Gbps NICs in the paper)
    rate_ai: float = 5e9 / 8           # R_AI bytes/s per additive-increase
                                       # step (ConnectX-class rp_ai_rate)
    rate_min: float = 1e6              # bytes/s floor
    dcqcn_g: float = 1.0 / 16.0        # alpha EWMA gain
    alpha_timer: float = 55e-6         # alpha-decay timer T_alpha (s)
    inc_timer: float = 55e-6           # rate-increase timer (s)
    fast_recovery_stages: int = 5      # stages before additive increase
    cnp_interval: float = 50e-6        # min time between honored CNPs (s)


class FlowCCState(NamedTuple):
    """Per-flow congestion-control state (arrays of shape [n_flows])."""

    cwnd: Array            # packets (window-based algos)
    ssthresh: Array        # packets
    cooldown: Array        # seconds until loss events are honored again
    # CUBIC
    w_max: Array           # packets at last decrease
    epoch_start: Array     # time of last decrease (s)
    # DCQCN
    rate_cur: Array        # bytes/s
    rate_target: Array     # bytes/s
    alpha: Array
    t_last_cnp: Array
    t_last_inc: Array
    t_last_alpha: Array
    inc_stage: Array       # int32


class Feedback(NamedTuple):
    """Per-tick, per-flow feedback (already delayed by RTT by the caller)."""

    num_acks: Array        # delivered bytes / MSS during the tick
    loss: Array            # bool: loss event signal (drop-based algos)
    cnp: Array             # bool: ECN/CNP congestion signal (DCQCN)
    now: Array             # scalar time (s)


def init_flow_state(n: int, params: CCParams, dtype=jnp.float32) -> FlowCCState:
    z = jnp.zeros((n,), dtype)
    return FlowCCState(
        cwnd=jnp.full((n,), params.init_cwnd, dtype),
        ssthresh=jnp.full((n,), params.init_ssthresh, dtype),
        cooldown=z,
        w_max=jnp.full((n,), params.init_cwnd, dtype),
        epoch_start=z,
        rate_cur=jnp.full((n,), params.line_rate, dtype),
        rate_target=jnp.full((n,), params.line_rate, dtype),
        alpha=jnp.ones((n,), dtype),
        t_last_cnp=z,
        t_last_inc=z,
        t_last_alpha=z,
        inc_stage=jnp.zeros((n,), jnp.int32),
    )


def send_rate(params: CCParams, state: FlowCCState) -> Array:
    """Instantaneous send rate in bytes/s implied by the CC state."""
    if params.algo == Algo.DCQCN:
        return state.rate_cur
    # mss/rtt folds to one python-float constant: a constant-divisor
    # division would invite XLA's per-program reciprocal rewrite and
    # 1-ulp drift between the fused-kernel and oracle programs
    return state.cwnd * (params.mss / params.rtt)
