"""TCP Reno +/- MLTCP (paper §3.4, Eqs. 4-7).

Additive increase (per ack batch):
    default:  cwnd += num_acks / cwnd                       (Eq. 4)
    MLTCP-WI: cwnd += F(bytes_ratio) * num_acks / cwnd      (Eq. 5)

Multiplicative decrease (per loss event, at most once per RTT):
    default:  cwnd  = 0.5 * cwnd                            (Eq. 6)
    MLTCP-MD: cwnd  = F(bytes_ratio) * 0.5 * cwnd           (Eq. 7)

Slow start is untouched (§3.4: "MLTCP does not make any changes to any other
parts of the congestion control algorithm").
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.cc.types import CCParams, Feedback, FlowCCState, Variant

Array = jnp.ndarray


def update(params: CCParams, state: FlowCCState, fb: Feedback,
           f_wi: Array, f_md: Array) -> FlowCCState:
    """One tick of Reno. ``f_wi``/``f_md`` are F(bytes_ratio) per flow, with
    the non-selected variant already forced to 1.0 by the caller."""
    cwnd = state.cwnd

    # ---- increase path (on acks) ----
    in_ss = cwnd < state.ssthresh
    grow_ss = fb.num_acks                                  # slow start: +1/ack
    grow_ca = f_wi * fb.num_acks / jnp.maximum(cwnd, 1e-6)  # Eq. 5
    cwnd_inc = cwnd + jnp.where(in_ss, grow_ss, grow_ca)

    # ---- decrease path (on loss events, once per RTT via cooldown) ----
    can_cut = state.cooldown <= 0.0
    do_cut = fb.loss & can_cut
    # Eq. 7, with F*beta clipped at 1 (a decrease never increases cwnd).
    cwnd_cut = jnp.maximum(jnp.minimum(f_md * params.reno_beta, 1.0) * cwnd,
                           params.min_cwnd)

    new_cwnd = jnp.where(do_cut, cwnd_cut, cwnd_inc)
    new_ssthresh = jnp.where(do_cut, jnp.maximum(cwnd_cut, 2.0), state.ssthresh)
    new_cooldown = jnp.where(do_cut, params.rtt,
                             jnp.maximum(state.cooldown - params.tick_dt, 0.0))

    return state._replace(cwnd=new_cwnd, ssthresh=new_ssthresh,
                          cooldown=new_cooldown)


def split_f(params: CCParams, f_vals: Array) -> tuple[Array, Array]:
    """Route F(bytes_ratio) to the WI and/or MD hook per the variant."""
    one = jnp.ones_like(f_vals)
    if params.variant == Variant.OFF:
        return one, one
    if params.variant == Variant.WI:
        return f_vals, one
    if params.variant == Variant.MD:
        return one, f_vals
    return f_vals, f_vals  # BOTH
