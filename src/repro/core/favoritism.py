"""Job favoritism policies — paper §3.2.

Which competing job should be "slid left" (given more bandwidth)?  Any policy
that *reinforces* Shortest-Remaining-Processing-Time stabilizes into an
interleaved state; any policy that cancels SRPT does not.  MLTCP uses
``bytes_sent / total_bytes`` (the fraction of the iteration already sent)
because it is computable *locally* at the sender with no central controller.

This module enumerates the policies discussed in §3.2 so that benchmarks and
property tests can verify the paper's claim: the four SRPT-reinforcing
policies interleave, the four SRPT-canceling ones do not.  Each policy maps
per-flow observables to a "favoritism score" in [0, 1]; the aggressiveness
function F is then applied to that score instead of raw bytes_ratio.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class FlowObservables:
    """Per-flow quantities available when computing the favoritism score.

    bytes_ratio      : bytes_sent / total_bytes of the current iteration.
    iter_start_ago   : seconds since this iteration's comm phase started.
    est_finish_in    : estimated seconds until the iteration's comm finishes
                       (remaining bytes / current rate), normalized.
    """

    bytes_ratio: Array
    iter_start_ago: Array
    est_finish_in: Array


PolicyFn = Callable[[FlowObservables], Array]


# --- SRPT-reinforcing policies (paper: these all interleave) ---------------

def largest_data_sent(obs: FlowObservables) -> Array:
    """MLTCP's default: favor the flow with the largest fraction sent."""
    return obs.bytes_ratio


def smallest_data_remaining(obs: FlowObservables) -> Array:
    return 1.0 - (1.0 - obs.bytes_ratio)  # == bytes_ratio; kept for clarity


def earliest_iter_start(obs: FlowObservables) -> Array:
    """Favor jobs whose iteration started earliest (needs normalization by a
    horizon; time-based policies require central coordination in practice —
    §3.2 — but are modeled here for the ablation)."""
    return jnp.clip(obs.iter_start_ago, 0.0, 1.0)


def earliest_iter_finish(obs: FlowObservables) -> Array:
    return 1.0 - jnp.clip(obs.est_finish_in, 0.0, 1.0)


# --- SRPT-canceling policies (paper: these all FAIL to interleave) ---------

def smallest_data_sent(obs: FlowObservables) -> Array:
    return 1.0 - obs.bytes_ratio


def largest_data_remaining(obs: FlowObservables) -> Array:
    return 1.0 - obs.bytes_ratio


def latest_iter_start(obs: FlowObservables) -> Array:
    return 1.0 - jnp.clip(obs.iter_start_ago, 0.0, 1.0)


def latest_iter_finish(obs: FlowObservables) -> Array:
    return jnp.clip(obs.est_finish_in, 0.0, 1.0)


REINFORCING = {
    "largest_data_sent": largest_data_sent,
    "smallest_data_remaining": smallest_data_remaining,
    "earliest_iter_start": earliest_iter_start,
    "earliest_iter_finish": earliest_iter_finish,
}

CANCELING = {
    "smallest_data_sent": smallest_data_sent,
    "largest_data_remaining": largest_data_remaining,
    "latest_iter_start": latest_iter_start,
    "latest_iter_finish": latest_iter_finish,
}

ALL_POLICIES = {**REINFORCING, **CANCELING}


def get_policy(name: str) -> PolicyFn:
    try:
        return ALL_POLICIES[name]
    except KeyError as e:
        raise ValueError(f"unknown favoritism policy {name!r}; "
                         f"choose from {sorted(ALL_POLICIES)}") from e
