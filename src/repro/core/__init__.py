"""MLTCP core — the paper's primary contribution.

Exports the bandwidth-aggressiveness function family (paper §3.3, Fig 5), the
job-favoritism policies (§3.2), the iteration-boundary detector (Algorithm 1),
and the congestion-control variants (Reno / CUBIC / DCQCN) with MLTCP's
window-increase (WI) and multiplicative-decrease (MD) augmentations (§3.4).
"""

from repro.core.aggressiveness import linear, make_fn, paper_functions
from repro.core.iteration import (
    IterDetectParams,
    IterDetectState,
    boundary_mask,
    run_on_trace,
    update_mltcp_params,
)
from repro.core.mltcp import (
    Algo,
    CCParams,
    DynamicParams,
    Feedback,
    FlowCCState,
    MLTCPConfig,
    MLTCPState,
    Variant,
    cc_tick,
    init_flow_state,
    init_state,
    send_rate,
)

__all__ = [
    "linear", "make_fn", "paper_functions",
    "IterDetectParams", "IterDetectState", "boundary_mask", "run_on_trace",
    "update_mltcp_params",
    "Algo", "CCParams", "DynamicParams", "Feedback", "FlowCCState",
    "MLTCPConfig", "MLTCPState",
    "Variant", "cc_tick", "init_flow_state", "init_state", "send_rate",
]
