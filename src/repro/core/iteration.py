"""Algorithm 1 — distributed iteration-boundary detection (paper §3.5).

Each flow tracks, purely from its own ack arrivals:

  bytes_sent        successfully delivered bytes in the current iteration
  bytes_ratio       min(1, bytes_sent / total_bytes)
  prev_ack_tstamp   timestamp of the previous ack
  iter_gap          EWMA estimate of the inter-iteration communication gap
  max_gap           max ack gap observed within the current iteration

On every ack: if the gap since the previous ack exceeds ``g * iter_gap`` the
flow declares a new training iteration, folds ``max_gap`` into the EWMA
estimate ``iter_gap`` (factor γ) and resets its byte counters.  This is how
MLTCP stays fully distributed: no controller tells a sender where iteration
boundaries are — it infers them from its own traffic, which also makes the
mechanism robust to multi-peak (pipeline/tensor-parallel) patterns, stragglers
and parameter updates landing mid-iteration (§5 Discussion).

Implemented as a pure function over a NamedTuple state so it can run (a)
vectorized over all flows inside the netsim `lax.scan`, (b) inside the Pallas
fused CC-tick kernel, and (c) standalone on recorded ack traces in tests.

Defaults follow Algorithm 1: g = 0.75, γ = 0.5, MTU = 1500.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray

# jax 0.4.x ships lax.optimization_barrier without a vmap batching rule;
# the rule is trivial (barrier each batched operand, keep the batch dims) and
# upstream in newer releases.  Registered here because `ack_bytes` below must
# work inside the vmapped sweep engine.  The private-module import is
# guarded: on a jax whose internal layout moved, the rule is upstream
# anyway and registration is simply skipped.
try:
    from jax._src.lax import lax as _lax_internal
    from jax.interpreters import batching as _batching
    _barrier_p = getattr(_lax_internal, "optimization_barrier_p", None)
except ImportError:                                    # pragma: no cover
    _barrier_p = None
if _barrier_p is not None and _barrier_p not in _batching.primitive_batchers:
    def _barrier_batcher(batched_args, batch_dims, **params):
        return _barrier_p.bind(*batched_args, **params), batch_dims
    _batching.primitive_batchers[_barrier_p] = _barrier_batcher


def ack_bytes(num_acks: Array, mtu) -> Array:
    """``num_acks * mtu`` — the bytes acked this tick — as a materialized
    product.

    The optimization barrier stops XLA from contracting the product into a
    neighbouring add (FMA).  XLA makes that choice *per program*, so without
    the barrier the fused-kernel program and the jnp-oracle program can
    round the same byte counter 1 ulp apart on some tick and drift
    irrecoverably over a simulation.  Single source of truth for every
    Algorithm 1 byte increment: `update_mltcp_params`, the job aggregation
    in `core.cc_tick`, and the fused-kernel wrapper (which passes it to the
    kernel as the precomputed ``ack_bytes`` operand); bit-equality of
    kernel and oracle sweeps is pinned by tests/test_sweep.py.
    """
    return jax.lax.optimization_barrier(num_acks * mtu)


def byte_ratio(numer: Array, total_bytes: Array) -> Array:
    """Algorithm 1 line 20: ``min(1, bytes_sent / total_bytes)``.

    Written as reciprocal-then-multiply deliberately: a literal division
    whose divisor is a trace-time constant (total_bytes usually is) invites
    XLA's divide-by-constant → multiply-by-reciprocal rewrite, and XLA
    makes that choice *per program* — the fused-kernel program and the
    jnp-oracle program could round the same tick 1 ulp apart and drift
    irrecoverably over a simulation.  An explicit reciprocal multiply is
    rewrite-proof (a multiply has no cheaper form), so both programs round
    identically.  The single source of truth for the ratio: used by
    ``update_mltcp_params`` below and inside the fused kernel body
    (`repro.kernels.mltcp_step`), pinned bit-equal by tests/test_sweep.py.
    """
    return jnp.minimum(1.0, numer * (1.0 / jnp.maximum(total_bytes, 1.0)))


class IterDetectParams(NamedTuple):
    """Static parameters of Algorithm 1 (per flow, broadcastable)."""

    total_bytes: Array          # total bytes per training iteration
    init_comm_gap: Array        # INIT_COMM_GAP: min gap for boundary detection (s)
    g: float = 0.75             # noise tolerance for gap detection
    gamma: float = 0.5          # EWMA factor for iter_gap
    mtu: float = 1500.0         # bytes per ack'd packet


class IterDetectState(NamedTuple):
    """Mutable per-flow state of Algorithm 1 (all arrays of shape [n_flows])."""

    bytes_sent: Array
    bytes_ratio: Array
    prev_ack_tstamp: Array
    iter_gap: Array
    max_gap: Array
    n_boundaries: Array         # number of boundaries detected (for metrics)


def init_state(n_flows: int, params: IterDetectParams,
               dtype=jnp.float32) -> IterDetectState:
    z = jnp.zeros((n_flows,), dtype)
    gap = jnp.broadcast_to(jnp.asarray(params.init_comm_gap, dtype), (n_flows,))
    return IterDetectState(
        bytes_sent=z,
        bytes_ratio=z,
        prev_ack_tstamp=z,
        iter_gap=gap,
        max_gap=gap,
        n_boundaries=jnp.zeros((n_flows,), jnp.int32),
    )


def boundary_mask(prev_ack_tstamp: Array, iter_gap: Array, g,
                  num_acks: Array, now: Array) -> Array:
    """Algorithm 1 line 16: does this ack open a new training iteration?

    The single source of truth for the boundary predicate — used by
    ``update_mltcp_params`` below and by the fused-kernel wrapper
    (`repro.kernels.ops.mltcp_cc_tick`) to maintain the ``n_boundaries``
    metrics counter, so the two paths cannot drift.
    """
    has_ack = num_acks > 0
    curr_gap = now - prev_ack_tstamp
    return has_ack & (curr_gap > g * iter_gap)


def update_mltcp_params(state: IterDetectState, params: IterDetectParams,
                        num_acks: Array, now: Array,
                        job_bytes_sent: Array | None = None) -> IterDetectState:
    """One invocation of UPDATE_MLTCP_PARAMS (Algorithm 1, lines 11-27).

    Vectorized over flows. ``num_acks`` is the number of acks received at time
    ``now`` for each flow (0 => no ack; the state is left untouched for those
    flows, as the hook only runs on ack receipt).

    ``job_bytes_sent``: optional job-aggregated bytes (the paper aggregates
    statistics across all sockets of a job — §4.1); when given it replaces the
    per-flow counter in the bytes_ratio computation.
    """
    has_ack = num_acks > 0

    bytes_sent = state.bytes_sent + ack_bytes(num_acks, params.mtu)  # line 12
    curr_gap = now - state.prev_ack_tstamp                         # line 14
    max_gap = jnp.maximum(state.max_gap, curr_gap)                 # line 15

    boundary = boundary_mask(state.prev_ack_tstamp, state.iter_gap,
                             params.g, num_acks, now)              # line 16
    # line 19: iter_gap EWMA folds in this iteration's max observed gap
    iter_gap_upd = (1.0 - params.gamma) * state.iter_gap + params.gamma * max_gap

    numer = job_bytes_sent if job_bytes_sent is not None else bytes_sent
    ratio_mid = byte_ratio(numer, params.total_bytes)

    return IterDetectState(
        # lines 21-22 (reset) vs line 12 (accumulate)
        bytes_sent=jnp.where(boundary, 0.0,
                             jnp.where(has_ack, bytes_sent, state.bytes_sent)),
        bytes_ratio=jnp.where(boundary, 0.0,
                              jnp.where(has_ack, ratio_mid, state.bytes_ratio)),
        prev_ack_tstamp=jnp.where(has_ack, now, state.prev_ack_tstamp),  # line 26
        iter_gap=jnp.where(boundary, iter_gap_upd, state.iter_gap),
        max_gap=jnp.where(boundary,
                          jnp.broadcast_to(params.init_comm_gap, max_gap.shape),
                          jnp.where(has_ack, max_gap, state.max_gap)),
        n_boundaries=state.n_boundaries + boundary.astype(jnp.int32),
    )


def run_on_trace(ack_times: Array, ack_counts: Array,
                 params: IterDetectParams) -> IterDetectState:
    """Run Algorithm 1 over a recorded (time, num_acks) trace for one flow.

    Returns the final state; used by unit/property tests to validate boundary
    detection against synthetic traffic with known iteration structure.
    """
    import jax

    st = init_state(1, params)

    def body(st, inp):
        t, n = inp
        return update_mltcp_params(st, params, jnp.atleast_1d(n),
                                   jnp.atleast_1d(t)), None

    st, _ = jax.lax.scan(body, st, (ack_times, ack_counts))
    return st
