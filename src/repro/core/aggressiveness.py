"""Bandwidth aggressiveness functions F(bytes_ratio)  — paper §3.3, Figure 5.

MLTCP scales congestion-control aggressiveness by ``F(bytes_ratio)`` where
``bytes_ratio = bytes_sent / total_bytes`` of the current training iteration.
The paper's requirements for a valid F (§3.3):

  (i)   the range is large enough to absorb network noise,
  (ii)  dF/dx >= 0 (non-negative derivative),
  (iii) all flows use the same F.

The default is the paper's linear function  F(x) = S*x + I  (Eq. 3).
This module also provides the six functions F1..F6 used in the ablation of
§4.8 / Figure 15 (F1..F4 increasing => interleave; F5, F6 decreasing => fail).

Everything here is a pure function of JAX scalars/arrays so that it can be
used inside `lax.scan` simulation loops and inside the Pallas CC-tick kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax.numpy as jnp

Array = jnp.ndarray
AggressivenessFn = Callable[[Array], Array]


@dataclasses.dataclass(frozen=True)
class LinearF:
    """The paper's Eq. 3:  F(bytes_ratio) = S * bytes_ratio + I.

    ``slope``/``intercept`` are tuned per congestion-control variant
    (paper §4.1): Reno-WI (1.75, 0.25), Reno-MD (1, 1), CUBIC-WI (1.0, 0.5),
    CUBIC-MD (0.8, 0.8), MLQCN (1.067, 0.267).
    """

    slope: float
    intercept: float

    def __call__(self, bytes_ratio: Array) -> Array:
        return self.slope * bytes_ratio + self.intercept


def linear(slope: float, intercept: float) -> LinearF:
    return LinearF(slope, intercept)


# ---------------------------------------------------------------------------
# The six ablation functions of §4.8 (all share range [0.25, 2] on x in [0,1]).
# ---------------------------------------------------------------------------

def _f1(x: Array) -> Array:  # linear increasing (the default shape)
    return 1.75 * x + 0.25


def _f2(x: Array) -> Array:  # convex increasing
    return 1.75 * x ** 2 + 0.25


def _f3(x: Array) -> Array:  # inverse increasing
    return 1.0 / (-3.5 * x + 4.0)


def _f4(x: Array) -> Array:  # concave increasing
    return -1.75 * x ** 2 + 3.5 * x + 0.25


def _f5(x: Array) -> Array:  # linear DECREASING (cancels SRPT; must fail)
    return -1.75 * x + 2.0


def _f6(x: Array) -> Array:  # concave DECREASING (must fail)
    return -1.75 * x ** 2 + 2.0


def paper_functions() -> Dict[str, AggressivenessFn]:
    """F1..F6 from §4.8 / Figure 15."""
    return {"F1": _f1, "F2": _f2, "F3": _f3, "F4": _f4, "F5": _f5, "F6": _f6}


_REGISTRY: Dict[str, AggressivenessFn] = dict(paper_functions())


def make_fn(spec: str | AggressivenessFn, slope: float | None = None,
            intercept: float | None = None) -> AggressivenessFn:
    """Resolve an aggressiveness function.

    ``spec`` may be a callable (used as-is), one of "F1".."F6", or "linear"
    (requires slope/intercept).  slope/intercept may be python floats *or*
    traced JAX scalars — the latter lets a vmapped parameter sweep vary
    Eq. 3 without retracing (DESIGN.md §3).
    """
    if callable(spec):
        return spec
    if spec == "linear":
        if slope is None or intercept is None:
            raise ValueError("linear F requires slope and intercept")
        return linear(slope, intercept)
    if spec in _REGISTRY:
        return _REGISTRY[spec]
    raise ValueError(f"unknown aggressiveness function {spec!r}")


def is_srpt_reinforcing(fn: AggressivenessFn, n: int = 256) -> bool:
    """Check requirement (ii): non-negative derivative over [0, 1].

    Used by property tests: increasing F => interleaves; decreasing => fails.
    """
    xs = jnp.linspace(0.0, 1.0, n)
    ys = fn(xs)
    return bool(jnp.all(jnp.diff(ys) >= -1e-7))
