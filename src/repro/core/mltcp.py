"""MLTCP — the composable protocol module (paper §3).

Ties together:
  * Algorithm 1 (iteration-boundary detection / bytes_ratio tracking),
  * the job-favoritism policy (§3.2),
  * the bandwidth-aggressiveness function F (§3.3),
  * one of the base congestion-control algorithms (§3.4).

`cc_tick` is the single vectorized update the netsim engine calls each tick;
it is also the pure-jnp oracle (`kernels/ref.py`) for the fused Pallas kernel
`kernels/mltcp_step.py`.

Baselines supported through the same entry point:
  * ``variant=OFF``            — default Reno/CUBIC/DCQCN.
  * ``static_factors=array``   — the Static scheme of [67]: a *constant*
    per-flow unfairness factor replaces F(bytes_ratio) (no dynamics).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.core import aggressiveness, favoritism as favoritism_mod
from repro.core import iteration
from repro.core.cc import cubic, dcqcn, reno
from repro.core.cc.types import (  # re-exported for convenience
    Algo,
    CCParams,
    Feedback,
    FlowCCState,
    Variant,
    init_flow_state,
    send_rate,
)

Array = jnp.ndarray

__all__ = [
    "Algo", "Variant", "CCParams", "FlowCCState", "Feedback",
    "MLTCPConfig", "MLTCPState", "DynamicParams", "init_state", "cc_tick",
    "f_values", "init_flow_state", "send_rate",
]


class DynamicParams(NamedTuple):
    """Traced protocol scalars — the dynamic half of the static/dynamic
    config split (DESIGN.md §3).

    ``MLTCPConfig`` holds everything that shapes the computation graph
    (algorithm, variant, favoritism policy, F family) and is a static jit
    argument; ``DynamicParams`` carries the *values* a parameter sweep
    varies, as JAX scalars that can be vmapped over a sweep axis without
    retracing.  ``from_config`` lifts a config's scalars; ``cc_tick`` uses
    a ``DynamicParams`` in preference to the config's baked-in floats.
    """

    slope: Array
    intercept: Array
    g: Array
    gamma: Array
    init_comm_gap: Array

    @staticmethod
    def from_config(cfg: "MLTCPConfig") -> "DynamicParams":
        return DynamicParams(
            slope=jnp.asarray(cfg.slope, jnp.float32),
            intercept=jnp.asarray(cfg.intercept, jnp.float32),
            g=jnp.asarray(cfg.g, jnp.float32),
            gamma=jnp.asarray(cfg.gamma, jnp.float32),
            init_comm_gap=jnp.asarray(cfg.init_comm_gap, jnp.float32),
        )


@dataclasses.dataclass(frozen=True)
class MLTCPConfig:
    """Static protocol configuration for one simulation/deployment."""

    cc: CCParams = CCParams()
    f_spec: str = "linear"              # "linear" | "F1".."F6" | callable
    slope: float = 1.75                 # S (paper §4.1 defaults for Reno-WI)
    intercept: float = 0.25             # I
    favoritism: str = "largest_data_sent"
    aggregate_by_job: bool = True       # paper §4.1: aggregate sockets per job
    # Algorithm 1 parameters
    init_comm_gap: float = 1e-3         # INIT_COMM_GAP (s)
    g: float = 0.75
    gamma: float = 0.5

    def f(self) -> aggressiveness.AggressivenessFn:
        return aggressiveness.make_fn(self.f_spec, self.slope, self.intercept)


class MLTCPState(NamedTuple):
    cc: FlowCCState
    det: iteration.IterDetectState


def init_state(n_flows: int, cfg: MLTCPConfig,
               dyn: Optional[DynamicParams] = None) -> MLTCPState:
    """Fresh protocol state; ``dyn`` overrides the config's traced scalars
    (the iter_gap estimate seeds from INIT_COMM_GAP)."""
    init_gap = cfg.init_comm_gap if dyn is None else dyn.init_comm_gap
    det_params = iteration.IterDetectParams(
        total_bytes=jnp.ones((n_flows,)),  # engine overwrites via params arg
        init_comm_gap=jnp.asarray(init_gap),
        g=cfg.g, gamma=cfg.gamma, mtu=cfg.cc.mss,
    )
    return MLTCPState(cc=init_flow_state(n_flows, cfg.cc),
                      det=iteration.init_state(n_flows, det_params))


_CC_UPDATES = {
    int(Algo.RENO): reno.update,
    int(Algo.CUBIC): cubic.update,
    int(Algo.DCQCN): dcqcn.update,
}


def _favoritism_score(cfg: MLTCPConfig, det: iteration.IterDetectState,
                      fb: Feedback, comm_elapsed: Optional[Array],
                      est_finish: Optional[Array]) -> Array:
    obs = favoritism_mod.FlowObservables(
        bytes_ratio=det.bytes_ratio,
        iter_start_ago=(comm_elapsed if comm_elapsed is not None
                        else jnp.zeros_like(det.bytes_ratio)),
        est_finish_in=(est_finish if est_finish is not None
                       else 1.0 - det.bytes_ratio),
    )
    return favoritism_mod.get_policy(cfg.favoritism)(obs)


def f_values(cfg: MLTCPConfig, det: iteration.IterDetectState,
             fb: Feedback, comm_elapsed: Optional[Array],
             est_finish: Optional[Array], dyn: DynamicParams,
             static_factors: Optional[Array] = None) -> Array:
    """Per-flow aggressiveness factors F for the current detection state.

    The factor stage of `cc_tick`, exposed on its own so observers (the
    netsim telemetry ``job_f`` probe) can recompute F from a post-update
    state without re-running the congestion-control update.
    """
    if cfg.cc.variant == int(Variant.OFF):
        adaptive = jnp.ones_like(det.bytes_ratio)
    else:
        score = _favoritism_score(cfg, det, fb, comm_elapsed, est_finish)
        fn = aggressiveness.make_fn(cfg.f_spec, dyn.slope, dyn.intercept)
        adaptive = fn(score)
    if static_factors is not None:
        # Static [67]: a non-negative factor replaces F for that flow; a
        # negative entry is the "adaptive" sentinel — that flow keeps the
        # computed F.  The sentinel lets Static and adaptive plan points
        # share one traced program (the factors are operand values), and
        # the select is exact: all-non-negative factors reproduce the pure
        # Static baseline bit-for-bit, all-negative the adaptive one.
        return jnp.where(static_factors >= 0.0, static_factors, adaptive)
    return adaptive


def cc_tick(cfg: MLTCPConfig,
            state: MLTCPState,
            fb: Feedback,
            total_bytes: Array,
            flow_to_job: Optional[Array] = None,
            n_jobs: int = 0,
            static_factors: Optional[Array] = None,
            comm_elapsed: Optional[Array] = None,
            est_finish: Optional[Array] = None,
            dyn: Optional[DynamicParams] = None) -> tuple[MLTCPState, Array]:
    """One protocol tick for all flows.

    Args:
      fb: RTT-delayed feedback (acks / loss / CNP signals) for this tick.
      total_bytes: per-flow bytes per training iteration (Algorithm 1 input).
      flow_to_job / n_jobs: socket→job map for per-job statistics aggregation.
      static_factors: if given, the Static [67] baseline — per-flow constant
        replaces F(bytes_ratio).
      dyn: traced protocol scalars (slope/intercept/g/gamma/init_comm_gap)
        replacing the config's static floats — the sweep-axis hook.
    Returns:
      (new_state, send_rate_bytes_per_s)
    """
    if dyn is None:
        dyn = DynamicParams.from_config(cfg)
    det_params = iteration.IterDetectParams(
        total_bytes=total_bytes,
        init_comm_gap=jnp.asarray(dyn.init_comm_gap),
        g=dyn.g, gamma=dyn.gamma, mtu=cfg.cc.mss,
    )

    # --- Algorithm 1: update bytes_sent / bytes_ratio / boundary detection ---
    job_bytes = None
    if cfg.aggregate_by_job and flow_to_job is not None and n_jobs > 0:
        per_flow_bytes = state.det.bytes_sent + iteration.ack_bytes(
            fb.num_acks, cfg.cc.mss)
        job_tot = jnp.zeros((n_jobs,), per_flow_bytes.dtype
                            ).at[flow_to_job].add(per_flow_bytes)
        job_bytes = job_tot[flow_to_job]
    det = iteration.update_mltcp_params(state.det, det_params, fb.num_acks,
                                        fb.now, job_bytes_sent=job_bytes)

    # --- favoritism score -> F values (or Static constants) ---
    f_vals = f_values(cfg, det, fb, comm_elapsed, est_finish, dyn,
                      static_factors=static_factors)

    f_wi, f_md = reno.split_f(cfg.cc, f_vals)

    # --- base congestion-control update with MLTCP scaling ---
    cc_state = _CC_UPDATES[int(cfg.cc.algo)](cfg.cc, state.cc, fb, f_wi, f_md)

    return MLTCPState(cc=cc_state, det=det), send_rate(cfg.cc, cc_state)
