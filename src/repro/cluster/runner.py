"""Shared-cluster simulation driver: N framework jobs on one DCN fabric."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import netsim, workload
from repro.cluster.profiles import profile_from_arch
from repro.configs import get_config
from repro.core import Algo, CCParams, MLTCPConfig, Variant


@dataclasses.dataclass
class ClusterReport:
    jobs: list[str]
    baseline_avg: list[float]
    mltcp_avg: list[float]
    avg_speedup: float
    p99_speedup: float
    interleave_before: float
    interleave_after: float


def simulate_shared_cluster(arch_ids: list[str], *, algo: str = "dcqcn",
                            sim_time: float = 4.0, seed: int = 0,
                            sockets_per_job: int = 2,
                            work_scale: float = 0.05) -> ClusterReport:
    """Run the assigned-architecture jobs as competing DCN traffic,
    default vs MLTCP congestion control.  ``work_scale`` shrinks all phase
    programs uniformly (ratio-preserving) to keep CPU wall time sane."""
    profiles = [profile_from_arch(get_config(a)).scaled(work_scale)
                for a in arch_ids]
    topo = netsim.dumbbell(len(arch_ids), sockets_per_job=sockets_per_job)
    jobs = workload.jobspec_from_profiles(profiles)
    dt = 2e-5
    algo_id = {"reno": Algo.RENO, "cubic": Algo.CUBIC,
               "dcqcn": Algo.DCQCN}[algo]
    slope, intercept = (1.067, 0.267) if algo == "dcqcn" else (1.75, 0.25)
    red = (dict(red_qmin=50e3, red_qmax=400e3, red_pmax=0.2)
           if algo == "dcqcn" else {})

    def build(pt):
        variant = Variant.WI if pt["scheme"] == "mltcp" else Variant.OFF
        proto = MLTCPConfig(
            cc=CCParams(algo=int(algo_id), variant=int(variant),
                        tick_dt=dt, rtt=100e-6),
            slope=slope, intercept=intercept)
        return netsim.SimConfig(topo=topo, jobs=jobs, protocol=proto,
                                sim_time=sim_time, dt=dt, seed=seed, **red)

    result = netsim.run_plan(netsim.Plan(
        name="shared-cluster",
        axes=(netsim.Axis("scheme", ("default", "mltcp")),),
        build=build))
    (base,), (ml,) = (result.select(scheme="default"),
                      result.select(scheme="mltcp"))
    sp = netsim.speedup_stats(base, ml)
    return ClusterReport(
        jobs=arch_ids,
        baseline_avg=[base.avg_iter(j) for j in range(len(arch_ids))],
        mltcp_avg=[ml.avg_iter(j) for j in range(len(arch_ids))],
        avg_speedup=sp["avg_speedup"],
        p99_speedup=sp["p99_speedup"],
        interleave_before=netsim.mean_pairwise_interleave(base),
        interleave_after=netsim.mean_pairwise_interleave(ml),
    )
