"""Per-iteration communication profiles of the assigned architectures.

The multi-pod deployment model (DESIGN.md §2): a job trains on 1-2 v5e pods;
within a pod, TP/EP traffic rides ICI, but the *data-parallel gradient
all-reduce across pods* rides the shared data-center network — that is the
traffic MLTCP schedules, and several jobs' pods share DCN links.

  comm_bytes/iter = 2 * (pods-1)/pods * grad_bytes        (ring all-reduce)
  compute_s/iter  = MODEL_FLOPS / (chips * peak * MFU) + intra-pod comm,
                    i.e. the roofline-informed step time with everything
                    except the DCN phase folded into the "compute" gap.

MoE archs add a second, smaller burst (expert-parallel spillover across
pods when experts outgrow one pod — llama4's 128 experts over 2 pods).
Gradient compression (repro.optim.grad_compress) plugs in by scaling
grad_bytes — the knob the paper's related work (QSGD/DGC) turns.
"""
from __future__ import annotations

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim.grad_compress import CompressionConfig, wire_bytes
from repro.roofline.hw import V5E
from repro.workload.comm_model import CommProfile


def profile_from_arch(cfg: ModelConfig, *, pods: int = 2,
                      chips_per_pod: int = 64,
                      tokens_per_iter: int = 16 * 4096,
                      mfu: float = 0.4,
                      grad_dtype_bytes: float = 2.0,
                      dcn_nics: int = 16,
                      compression: CompressionConfig | None = None,
                      hw=V5E) -> CommProfile:
    """Defaults model the *contended* regime the paper studies: modest
    fine-tuning slices (64 chips/pod, 64k-token batches) whose cross-pod
    gradient all-reduce rides ``dcn_nics`` shared 50 Gbps DCN uplinks —
    large-batch full-pod jobs are compute-dominated and rarely contend."""
    n_params = transformer.param_count(cfg)
    n_active = transformer.active_param_count(cfg)

    grad_bytes = n_params * grad_dtype_bytes
    if compression is not None and compression.scheme != "none":
        grad_bytes = wire_bytes(compression, n_params, pods) \
            / (2.0 * (pods - 1) / pods)
    dcn_bytes = 2.0 * (pods - 1) / pods * grad_bytes / dcn_nics
    # bytes per shared DCN uplink of the cross-pod all-reduce

    flops = 6.0 * n_active * tokens_per_iter
    compute_s = flops / (pods * chips_per_pod * hw.peak_flops_bf16 * mfu)

    if cfg.moe is not None and pods > 1:
        # expert-parallel all-to-all spillover across pods: each token's
        # hidden vector crosses the DCN once in each direction for the
        # fraction of experts living on the other pod
        frac_remote = (pods - 1) / pods
        a2a = (2.0 * tokens_per_iter * cfg.moe.top_k * cfg.d_model
               * grad_dtype_bytes * frac_remote) / (pods * dcn_nics)
        return CommProfile(
            name=cfg.name,
            compute_s=(compute_s * 0.6, compute_s * 0.4),
            comm_bytes=(a2a, dcn_bytes),
            parallelism="dp+ep",
        )
    return CommProfile(name=cfg.name, compute_s=(compute_s,),
                       comm_bytes=(dcn_bytes,), parallelism="dp")
