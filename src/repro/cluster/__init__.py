"""cluster — shared-cluster simulation of the framework's own training jobs.

Bridges the two halves of the system: the trainer side computes each
(architecture x parallelization) job's per-iteration communication profile
(the `total_bytes` MLTCP needs and the compute gaps between bursts), and the
netsim side runs those jobs as competing traffic under MLTCP or baselines.
"""

from repro.cluster.profiles import profile_from_arch
from repro.cluster.runner import simulate_shared_cluster

__all__ = ["profile_from_arch", "simulate_shared_cluster"]
