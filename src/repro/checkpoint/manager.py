"""Checkpoint manager — fault-tolerance substrate.

Design (1000+-node posture, DESIGN.md §6):
  * **atomic commit**: writes land in ``step_N.tmp`` and are renamed to
    ``step_N`` only after every leaf + manifest is durably written, so a
    preempted save can never be mistaken for a valid checkpoint;
  * **mesh-agnostic**: leaves are stored as full logical arrays + the
    manifest records the tree structure; restore re-shards onto whatever
    mesh/PartitionSpec the *new* job uses (elastic shrink/grow) — on a real
    multi-host pod each process would write its addressable shards instead
    (same manifest format, per-shard files);
  * **async**: array serialization runs on a background thread; `wait()`
    joins before the next save or program exit;
  * **keep-N retention** + automatic latest-step discovery for restarts.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _leaf_name(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return _SAFE.sub("_", ".".join(parts)) or "leaf"


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # gather to host

        def work():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            leaves = jax.tree_util.tree_flatten_with_path(host_tree)[0]
            manifest = {"step": step, "leaves": []}
            seen: dict[str, int] = {}
            for path, leaf in leaves:
                name = _leaf_name(path)
                if name in seen:           # disambiguate collisions
                    seen[name] += 1
                    name = f"{name}__{seen[name]}"
                else:
                    seen[name] = 0
                np.save(os.path.join(tmp, name + ".npy"), leaf,
                        allow_pickle=False)
                manifest["leaves"].append(
                    {"file": name + ".npy",
                     "shape": list(leaf.shape),
                     "dtype": str(leaf.dtype)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic commit
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; optionally device_put each
        leaf with the matching sharding (elastic re-shard on load)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = [np.load(os.path.join(d, rec["file"]), allow_pickle=False)
                  for rec in manifest["leaves"]]
        flat_like, treedef = jax.tree_util.tree_flatten(like)
        if len(flat_like) != len(arrays):
            raise ValueError(
                f"checkpoint has {len(arrays)} leaves, expected "
                f"{len(flat_like)} — incompatible tree")
        if shardings is not None:
            flat_sh = jax.tree_util.tree_flatten(shardings)[0]
            out = [jax.device_put(a.astype(l.dtype), s)
                   for a, l, s in zip(arrays, flat_like, flat_sh)]
        else:
            out = [jnp.asarray(a.astype(l.dtype)) for a, l in
                   zip(arrays, flat_like)]
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------
    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for d in os.listdir(self.dir)
            if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[: max(0, len(steps) - self.keep_n)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
