"""The unified decoder stack driving all 10 assigned architectures.

Layer loop structure (compile-friendly for the 512-device dry-run):

    [lead blocks]  first_k_dense DeepSeekMoE-style dense layers, unscanned
    [scan groups]  n_groups repetitions of cfg.block_pattern, parameters
                   stacked on a leading axis and stepped with lax.scan
                   (keeps HLO size O(group), lets remat wrap one group)
    [tail blocks]  pattern remainder when n_layers % len(pattern) != 0

Block kinds: "attn" (global), "attn_local" (sliding window), "rec" (RG-LRU),
"mlstm", "slstm".  FFN kinds per position: "dense" | "moe" | "none".
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe as moe_mod, rglru, xlstm
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, embed_init, norm, norm_param

Array = jnp.ndarray
Params = Any


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str, ffn_kind: str,
                d_ff: int) -> dict:
    k1, k2 = jax.random.split(key)
    p: dict = {"norm1": norm_param(cfg, cfg.d_model)}
    if kind in ("attn", "attn_local"):
        p["attn"] = attention.init_attn(k1, cfg)
    elif kind == "rec":
        p["rec"] = rglru.init_rglru_block(k1, cfg)
    elif kind == "mlstm":
        p["mlstm"] = xlstm.init_mlstm_block(k1, cfg)
    elif kind == "slstm":
        p["slstm"] = xlstm.init_slstm_block(k1, cfg)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if cfg.post_norm:
        p["postnorm1"] = norm_param(cfg, cfg.d_model)
    if ffn_kind == "dense":
        p["norm2"] = norm_param(cfg, cfg.d_model)
        p["ffn"] = layers.init_mlp(k2, cfg.d_model, d_ff)
        if cfg.post_norm:
            p["postnorm2"] = norm_param(cfg, cfg.d_model)
    elif ffn_kind == "moe":
        p["norm2"] = norm_param(cfg, cfg.d_model)
        p["moe"] = moe_mod.init_moe(k2, cfg)
        if cfg.post_norm:
            p["postnorm2"] = norm_param(cfg, cfg.d_model)
    return p


def _apply_block(cfg: ModelConfig, kind: str, ffn_kind: str, p: dict,
                 h: Array, positions: Array, use_kernel: bool
                 ) -> tuple[Array, Array]:
    aux = jnp.zeros((), jnp.float32)
    x = norm(cfg, h, p["norm1"])
    if kind == "attn":
        y = attention.attn_forward(p["attn"], cfg, x, positions=positions,
                                   use_kernel=use_kernel)
    elif kind == "attn_local":
        y = attention.attn_forward(p["attn"], cfg, x, positions=positions,
                                   window=cfg.window, use_kernel=use_kernel)
    elif kind == "rec":
        y = rglru.rglru_forward(p["rec"], cfg, x, use_kernel=use_kernel)
    elif kind == "mlstm":
        y = xlstm.mlstm_forward(p["mlstm"], cfg, x)
    elif kind == "slstm":
        y = xlstm.slstm_forward(p["slstm"], cfg, x)
    else:
        raise ValueError(kind)
    if cfg.post_norm:
        y = norm(cfg, y, p["postnorm1"])
    h = h + y

    if ffn_kind in ("dense", "moe"):
        x = norm(cfg, h, p["norm2"])
        if ffn_kind == "dense":
            y = layers.mlp(p["ffn"], x)
        else:
            y, aux = moe_mod.moe_forward(p["moe"], cfg, x)
        if cfg.post_norm:
            y = norm(cfg, y, p["postnorm2"])
        h = h + y
    return h, aux


def _decode_block(cfg: ModelConfig, kind: str, ffn_kind: str, p: dict,
                  h: Array, cache: dict, index: Array) -> tuple[Array, dict]:
    x = norm(cfg, h, p["norm1"])
    if kind == "attn":
        y, new_cache = attention.attn_decode(p["attn"], cfg, x, cache, index)
    elif kind == "attn_local":
        y, new_cache = attention.attn_decode_ring(p["attn"], cfg, x, cache,
                                                  index, window=cfg.window)
    elif kind == "rec":
        y, new_cache = rglru.rglru_decode(p["rec"], cfg, x, cache)
    elif kind == "mlstm":
        y, new_cache = xlstm.mlstm_decode(p["mlstm"], cfg, x, cache)
    elif kind == "slstm":
        y, new_cache = xlstm.slstm_decode(p["slstm"], cfg, x, cache)
    else:
        raise ValueError(kind)
    if cfg.post_norm:
        y = norm(cfg, y, p["postnorm1"])
    h = h + y
    if ffn_kind in ("dense", "moe"):
        x = norm(cfg, h, p["norm2"])
        if ffn_kind == "dense":
            y = layers.mlp(p["ffn"], x)
        else:
            y, _ = moe_mod.moe_forward(p["moe"], cfg, x)
        if cfg.post_norm:
            y = norm(cfg, y, p["postnorm2"])
        h = h + y
    return h, new_cache


def _block_plan(cfg: ModelConfig):
    """(lead, pattern, n_groups, tail) block/ffn kind lists."""
    pattern = list(zip(cfg.block_pattern, cfg.ffn_kinds))
    lead = [("attn", "dense")] * cfg.first_k_dense
    n_rest = cfg.n_layers - len(lead)
    n_groups = n_rest // len(pattern)
    tail = pattern[: n_rest - n_groups * len(pattern)]
    return lead, pattern, n_groups, tail


# ---------------------------------------------------------------------------
# Model init / forward
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: Array) -> Params:
    lead, pattern, n_groups, tail = _block_plan(cfg)
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    p: dict = {"embed": embed_init(keys[0], (cfg.vocab_padded, d))}
    if cfg.vit_dim:
        p["proj_vision"] = dense_init(keys[1], (cfg.vit_dim, d))
    lead_ff = cfg.dense_d_ff or cfg.d_ff

    def init_group(gkey):
        ks = jax.random.split(gkey, len(pattern))
        return {f"b{i}": _init_block(ks[i], cfg, kind, ffn, cfg.d_ff)
                for i, (kind, ffn) in enumerate(pattern)}

    if lead:
        lks = jax.random.split(keys[2], len(lead))
        p["lead"] = {str(i): _init_block(lks[i], cfg, k, f, lead_ff)
                     for i, (k, f) in enumerate(lead)}
    if n_groups:
        gks = jax.random.split(keys[3], n_groups)
        groups = [init_group(gks[g]) for g in range(n_groups)]
        p["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    if tail:
        tks = jax.random.split(keys[4], len(tail))
        p["tail"] = {str(i): _init_block(tks[i], cfg, k, f, cfg.d_ff)
                     for i, (k, f) in enumerate(tail)}
    p["final_norm"] = norm_param(cfg, d)
    if not cfg.tie_embeddings:
        p["head"] = dense_init(keys[5], (d, cfg.vocab_padded))
    return p


def embed_inputs(cfg: ModelConfig, params: Params, tokens: Array,
                 extra_embeds: Optional[Array] = None) -> Array:
    h = params["embed"][tokens]
    if cfg.embed_scale:
        h = h * jnp.sqrt(cfg.d_model).astype(h.dtype)
    if extra_embeds is not None:
        if cfg.vit_dim:
            extra_embeds = extra_embeds @ params["proj_vision"]
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=1)
    return h


def forward(cfg: ModelConfig, params: Params, tokens: Array,
            extra_embeds: Optional[Array] = None, use_kernel: bool = False,
            remat: bool = True, unroll: bool = False) -> tuple[Array, Array]:
    """Returns (logits [B, T, V], aux_loss scalar). ``unroll`` replaces the
    layer-group scan with a python loop (roofline L1/L2 lowers need every op
    instance visible because XLA's cost analysis counts a while body once)."""
    lead, pattern, n_groups, tail = _block_plan(cfg)
    h = embed_inputs(cfg, params, tokens, extra_embeds)
    positions = jnp.broadcast_to(jnp.arange(h.shape[1]), h.shape[:2])
    aux = jnp.zeros((), jnp.float32)

    for i, (kind, ffn) in enumerate(lead):
        h, a = _apply_block(cfg, kind, ffn, params["lead"][str(i)], h,
                            positions, use_kernel)
        aux = aux + a

    if n_groups:
        def group_fn(carry, gparams):
            h, aux = carry
            for i, (kind, ffn) in enumerate(pattern):
                h, a = _apply_block(cfg, kind, ffn, gparams[f"b{i}"], h,
                                    positions, use_kernel)
                aux = aux + a
            return (h, aux), None

        if remat:
            group_fn = jax.checkpoint(group_fn)
        if unroll:
            for g in range(n_groups):
                gp = jax.tree.map(lambda x: x[g], params["groups"])
                (h, aux), _ = group_fn((h, aux), gp)
        else:
            (h, aux), _ = jax.lax.scan(group_fn, (h, aux), params["groups"])

    for i, (kind, ffn) in enumerate(tail):
        h, a = _apply_block(cfg, kind, ffn, params["tail"][str(i)], h,
                            positions, use_kernel)
        aux = aux + a

    h = norm(cfg, h, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = h @ head
    logits = layers.softcap(logits, cfg.logit_softcap)
    return logits, aux


# ---------------------------------------------------------------------------
# Prefill: forward pass that also emits decode caches
# ---------------------------------------------------------------------------

def _apply_block_prefill(cfg, kind: str, ffn_kind: str, p: dict, h: Array,
                         positions: Array, use_kernel: bool, max_len: int
                         ) -> tuple[Array, Array, dict]:
    t = h.shape[1]
    batch = h.shape[0]
    aux = jnp.zeros((), jnp.float32)
    x = norm(cfg, h, p["norm1"])
    if kind in ("attn", "attn_local"):
        window = cfg.window if kind == "attn_local" else 0
        y, (k, v) = attention.attn_forward(
            p["attn"], cfg, x, positions=positions, window=window,
            use_kernel=use_kernel, return_kv=True)
        if kind == "attn":
            cache = attention.fill_kv_cache(
                attention.init_kv_cache(cfg, batch, max_len, h.dtype), k, v)
        else:
            w = min(cfg.window or max_len, max_len)
            cache = attention.fill_ring_cache(
                attention.init_ring_cache(cfg, batch, w, h.dtype), k, v, t)
    elif kind == "rec":
        y, cache = rglru.rglru_forward(p["rec"], cfg, x,
                                       use_kernel=use_kernel,
                                       return_state=True)
    elif kind == "mlstm":
        y, cache = xlstm.mlstm_forward(p["mlstm"], cfg, x, return_state=True)
    elif kind == "slstm":
        y, cache = xlstm.slstm_forward(p["slstm"], cfg, x, return_state=True)
    else:
        raise ValueError(kind)
    if cfg.post_norm:
        y = norm(cfg, y, p["postnorm1"])
    h = h + y
    if ffn_kind in ("dense", "moe"):
        x = norm(cfg, h, p["norm2"])
        if ffn_kind == "dense":
            y = layers.mlp(p["ffn"], x)
        else:
            y, aux = moe_mod.moe_forward(p["moe"], cfg, x)
        if cfg.post_norm:
            y = norm(cfg, y, p["postnorm2"])
        h = h + y
    return h, aux, cache


def prefill(cfg: ModelConfig, params: Params, tokens: Array, max_len: int,
            extra_embeds: Optional[Array] = None, use_kernel: bool = False,
            unroll: bool = False) -> tuple[Array, dict]:
    """Process a prompt, returning (last-position logits [B, V], cache)."""
    lead, pattern, n_groups, tail = _block_plan(cfg)
    h = embed_inputs(cfg, params, tokens, extra_embeds)
    positions = jnp.broadcast_to(jnp.arange(h.shape[1]), h.shape[:2])
    cache: dict = {}

    if lead:
        cache["lead"] = {}
        for i, (kind, ffn) in enumerate(lead):
            h, _, cc = _apply_block_prefill(cfg, kind, ffn,
                                            params["lead"][str(i)], h,
                                            positions, use_kernel, max_len)
            cache["lead"][str(i)] = cc

    if n_groups:
        def group_fn(h, gparams):
            out_cache = {}
            for i, (kind, ffn) in enumerate(pattern):
                h, _, cc = _apply_block_prefill(cfg, kind, ffn,
                                                gparams[f"b{i}"], h,
                                                positions, use_kernel,
                                                max_len)
                out_cache[f"b{i}"] = cc
            return h, out_cache

        if unroll:
            caches = []
            for g in range(n_groups):
                gp = jax.tree.map(lambda x: x[g], params["groups"])
                h, cc = group_fn(h, gp)
                caches.append(cc)
            cache["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
        else:
            h, cache["groups"] = jax.lax.scan(group_fn, h, params["groups"])

    if tail:
        cache["tail"] = {}
        for i, (kind, ffn) in enumerate(tail):
            h, _, cc = _apply_block_prefill(cfg, kind, ffn,
                                            params["tail"][str(i)], h,
                                            positions, use_kernel, max_len)
            cache["tail"][str(i)] = cc

    h = norm(cfg, h, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = layers.softcap(h[:, -1] @ head, cfg.logit_softcap)
    return logits, cache


# ---------------------------------------------------------------------------
# Decode (single token, KV/recurrent caches)
# ---------------------------------------------------------------------------

def _init_block_cache(cfg, kind: str, batch: int, max_len: int, dtype):
    if kind == "attn":
        return attention.init_kv_cache(cfg, batch, max_len, dtype)
    if kind == "attn_local":
        w = min(cfg.window or max_len, max_len)
        return attention.init_ring_cache(cfg, batch, w, dtype)
    if kind == "rec":
        return rglru.init_rglru_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm.init_mlstm_cache(cfg, batch, dtype)
    if kind == "slstm":
        return xlstm.init_slstm_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32) -> dict:
    lead, pattern, n_groups, tail = _block_plan(cfg)
    c: dict = {}
    if lead:
        c["lead"] = {str(i): _init_block_cache(cfg, k, batch, max_len, dtype)
                     for i, (k, _) in enumerate(lead)}
    if n_groups:
        one = {f"b{i}": _init_block_cache(cfg, k, batch, max_len, dtype)
               for i, (k, _) in enumerate(pattern)}
        c["groups"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), one)
    if tail:
        c["tail"] = {str(i): _init_block_cache(cfg, k, batch, max_len, dtype)
                     for i, (k, _) in enumerate(tail)}
    return c


def decode_step(cfg: ModelConfig, params: Params, cache: dict, token: Array,
                index: Array, unroll: bool = False) -> tuple[Array, dict]:
    """token: [B] int32; index: scalar position. Returns (logits [B,V], cache)."""
    lead, pattern, n_groups, tail = _block_plan(cfg)
    h = params["embed"][token][:, None, :]
    if cfg.embed_scale:
        h = h * jnp.sqrt(cfg.d_model).astype(h.dtype)
    new_cache: dict = {}

    if lead:
        new_cache["lead"] = {}
        for i, (kind, ffn) in enumerate(lead):
            h, cc = _decode_block(cfg, kind, ffn, params["lead"][str(i)], h,
                                  cache["lead"][str(i)], index)
            new_cache["lead"][str(i)] = cc

    if n_groups:
        def group_fn(h, xs):
            gparams, gcache = xs
            out_cache = {}
            for i, (kind, ffn) in enumerate(pattern):
                h, cc = _decode_block(cfg, kind, ffn, gparams[f"b{i}"], h,
                                      gcache[f"b{i}"], index)
                out_cache[f"b{i}"] = cc
            return h, out_cache

        if unroll:
            caches = []
            for g in range(n_groups):
                sl = lambda x: x[g]
                h, cc = group_fn(h, (jax.tree.map(sl, params["groups"]),
                                     jax.tree.map(sl, cache["groups"])))
                caches.append(cc)
            new_cache["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                               *caches)
        else:
            h, new_cache["groups"] = jax.lax.scan(
                group_fn, h, (params["groups"], cache["groups"]))

    if tail:
        new_cache["tail"] = {}
        for i, (kind, ffn) in enumerate(tail):
            h, cc = _decode_block(cfg, kind, ffn, params["tail"][str(i)], h,
                                  cache["tail"][str(i)], index)
            new_cache["tail"][str(i)] = cc

    h = norm(cfg, h, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = layers.softcap(h[:, 0] @ head, cfg.logit_softcap)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Parameter accounting (for MODEL_FLOPS = 6*N*D)
# ---------------------------------------------------------------------------

def param_count(cfg: ModelConfig) -> int:
    import math
    if cfg.enc_layers > 0:
        from repro.models import encdec
        shapes = jax.eval_shape(lambda k: encdec.init_params(cfg, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
    else:
        shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token: total minus the routed experts not selected
    and minus the embedding lookup table (gather, not matmul)."""
    total = param_count(cfg)
    embed = cfg.vocab * cfg.d_model
    if cfg.moe is None:
        return total - (embed if not cfg.tie_embeddings else 0)
    m = cfg.moe
    de = m.d_expert or cfg.d_ff
    per_expert = 3 * cfg.d_model * de
    _, pattern, n_groups, tail = _block_plan(cfg)
    kinds = (list(pattern) * n_groups) + list(tail)
    n_moe_layers = sum(1 for _, f in kinds if f == "moe")
    inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
    return total - inactive - (embed if not cfg.tie_embeddings else 0)
