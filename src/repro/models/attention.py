"""GQA attention covering the assigned archs' feature matrix.

Features (config-driven): grouped KV heads, RoPE, qk-norm (Qwen3), QKV bias
(Qwen1.5), attention-logit softcap (Gemma-2), local sliding window
(Gemma-2 / RecurrentGemma / Llama-4 chunked-local), KV cache decode, and an
optional cross-attention mode (seamless-m4t decoder).

The full-sequence path can route through the Pallas flash-attention kernel
(`repro.kernels.ops.flash_attention`); the jnp path here doubles as its
oracle and as the backward recompute rule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import dense_init, rope, softcap

Array = jnp.ndarray


def init_attn(key, cfg, cross: bool = False) -> dict:
    d, h, k, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], (d, h, dh), in_axis=0),
        "wk": dense_init(ks[1], (d, k, dh), in_axis=0),
        "wv": dense_init(ks[2], (d, k, dh), in_axis=0),
        "wo": dense_init(ks[3], (h, dh, d), in_axis=0),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), jnp.float32)
        p["bk"] = jnp.zeros((k, dh), jnp.float32)
        p["bv"] = jnp.zeros((k, dh), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), jnp.float32)
        p["k_norm"] = jnp.zeros((dh,), jnp.float32)
    return p


def _project_qkv(params, cfg, x: Array, kv_x: Array):
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, params["k_norm"], cfg.norm_eps)
    return q, k, v


# --- hillclimb knobs (set by the perf harness; see EXPERIMENTS.md §Perf) ---
# Shard the query/scores sequence axis over this mesh axis in full-sequence
# attention (context parallelism): cuts the [T, S] probs bytes by the axis
# size when heads cannot shard (e.g. qwen1.5's 20 heads on a 16-way axis).
SEQ_SHARD_AXIS: str | None = None
# Decode GQA via grouped einsum instead of materializing repeated KV heads
# (avoids the partitioner all-gathering the whole KV cache per step).
DECODE_GROUPED_GQA: bool = True


def _seq_shard(x: Array, axis: int = 1) -> Array:
    if SEQ_SHARD_AXIS is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = [None] * x.ndim
    spec[axis] = SEQ_SHARD_AXIS
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


def _expand_kv(k: Array, n_heads: int) -> Array:
    """Repeat KV heads to match query heads. A plain repeat (not a 5-D
    grouped reshape) keeps GSPMD head-sharding propagation clean — the
    grouped-einsum formulation triggers involuntary full rematerialization
    in the partitioner (observed on the 16x16 dry-run)."""
    g = n_heads // k.shape[2]
    return k if g == 1 else jnp.repeat(k, g, axis=2)


def _grouped_decode_attend(cfg, q, ck, cv, valid) -> Array:
    """Decode attention without expanding KV: q [B,1,H,D] reshaped to
    [B,1,K,g,D] against the cache [B,S,K,D] directly."""
    b, t, h, dh = q.shape
    kh = ck.shape[2]
    g = h // kh
    qg = q.reshape(b, t, kh, g, dh)
    scores = jnp.einsum("btkgd,bskd->btkgs", qg, ck) \
        / jnp.sqrt(dh).astype(q.dtype)
    scores = softcap(scores, cfg.attn_softcap)
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("btkgs,bskd->btkgd", probs, cv)
    return out.reshape(b, t, h, dh)


# materialized [T, S] probs above this threshold would blow VMEM/HBM; chunk
# queries instead (flash-style memory behavior in plain jnp)
_CHUNK_THRESHOLD = 2 ** 24
_Q_CHUNK = 1024


def _attend_dense(cfg, q, k, v, *, causal, window, q_offset):
    b, t, h, dh = q.shape
    s = k.shape[1]
    q = _seq_shard(q)
    scores = jnp.einsum("bthd,bshd->bths", q, k) / jnp.sqrt(dh).astype(q.dtype)
    scores = softcap(scores, cfg.attn_softcap)
    qpos = q_offset + jnp.arange(t)
    kpos = jnp.arange(s)
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window and window > 0:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    scores = jnp.where(mask[None, :, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bths,bshd->bthd", probs, v)


def attend(cfg, q: Array, k: Array, v: Array, *, causal: bool,
           window: int = 0, q_offset: Array | int = 0) -> Array:
    """Reference scaled-dot-product GQA attention.

    q: [B, T, H, D];  k/v: [B, S, K, D];  H = K * group.
    ``q_offset``: absolute position of q[0] (decode: cache length so far).

    For large T*S the [T, S] probability matrix is never materialized:
    queries are processed in _Q_CHUNK slices via lax.map (keeps HLO small and
    peak memory O(chunk * S) — the jnp analogue of the flash kernel, and the
    oracle it is tested against).
    """
    b, t, h, dh = q.shape
    s = k.shape[1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    if t * s <= _CHUNK_THRESHOLD or t % _Q_CHUNK != 0:
        return _attend_dense(cfg, q, k, v, causal=causal, window=window,
                             q_offset=q_offset)

    n_chunks = t // _Q_CHUNK
    qc = q.reshape(b, n_chunks, _Q_CHUNK, h, dh)

    def one_chunk(args):
        qi, off = args                        # qi: [b, chunk, h, dh]
        return _attend_dense(cfg, qi, k, v, causal=causal,
                             window=window, q_offset=q_offset + off)

    offs = jnp.arange(n_chunks) * _Q_CHUNK
    out = jax.lax.map(one_chunk, (jnp.moveaxis(qc, 1, 0), offs))
    return jnp.moveaxis(out, 0, 1).reshape(b, t, h, dh)


def attn_forward(params, cfg, x: Array, *, positions: Array,
                 kv_x: Array | None = None, causal: bool = True,
                 window: int = 0, use_kernel: bool = False,
                 return_kv: bool = False):
    """Full-sequence attention (training / prefill / encoder / cross)."""
    cross = kv_x is not None
    q, k, v = _project_qkv(params, cfg, x, x if kv_x is None else kv_x)
    if not cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if use_kernel and not cross:
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.flash_attention(
            q, k, v, causal=causal, window=window,
            softcap=cfg.attn_softcap)
    else:
        out = attend(cfg, q, k, v, causal=causal and not cross, window=window)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    if return_kv:
        return y, (k, v)
    return y


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.float32) -> dict:
    k, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, k, dh), dtype),
        "v": jnp.zeros((batch, max_len, k, dh), dtype),
    }


def init_ring_cache(cfg, batch: int, window: int, dtype=jnp.float32) -> dict:
    """Fixed-size rotating KV cache for sliding-window layers: O(window)
    memory regardless of sequence length (essential for long_500k)."""
    k, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, window, k, dh), dtype),
        "v": jnp.zeros((batch, window, k, dh), dtype),
        "pos": jnp.full((window,), -1, jnp.int32),
    }


def attn_decode_ring(params, cfg, x: Array, cache: dict, index: Array, *,
                     window: int) -> tuple[Array, dict]:
    """One-token decode against a ring KV cache. x: [B, 1, D]."""
    positions = jnp.full((x.shape[0], 1), index, jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, x)
    q = rope(q, positions, cfg.rope_theta)
    k_new = rope(k_new, positions, cfg.rope_theta)  # rotate at write time

    w = cache["k"].shape[1]
    slot = jnp.mod(index, w)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((1,), index, jnp.int32), slot, axis=0)

    b, t, h, dh = q.shape
    valid = (pos >= 0) & (pos <= index) & (pos > index - window)
    if DECODE_GROUPED_GQA:
        out = _grouped_decode_attend(cfg, q, ck, cv, valid)
    else:
        ke = _expand_kv(ck, h)
        ve = _expand_kv(cv, h)
        scores = jnp.einsum("bthd,bshd->bths", q, ke) \
            / jnp.sqrt(dh).astype(q.dtype)
        scores = softcap(scores, cfg.attn_softcap)
        scores = jnp.where(valid[None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1
                               ).astype(q.dtype)
        out = jnp.einsum("bths,bshd->bthd", probs, ve)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, {"k": ck, "v": cv, "pos": pos}


def fill_kv_cache(cache: dict, k: Array, v: Array) -> dict:
    """Write prefill K/V [B, T, K, D] into a zero-init full cache at [0:T]."""
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1)
    return {"k": ck, "v": cv}


def fill_ring_cache(cache: dict, k: Array, v: Array, t: int) -> dict:
    """Write the last `window` prefill K/V into a ring cache, slot = pos % W."""
    w = cache["k"].shape[1]
    take = min(w, t)
    # positions of the kept tail, placed at their ring slots
    tail_pos = jnp.arange(t - take, t)
    slots = jnp.mod(tail_pos, w)
    ck = cache["k"].at[:, slots].set(k[:, t - take: t])
    cv = cache["v"].at[:, slots].set(v[:, t - take: t])
    pos = cache["pos"].at[slots].set(tail_pos.astype(jnp.int32))
    return {"k": ck, "v": cv, "pos": pos}


def attn_decode(params, cfg, x: Array, cache: dict, index: Array, *,
                window: int = 0) -> tuple[Array, dict]:
    """One-token decode step. x: [B, 1, D]; cache k/v: [B, S, K, D]."""
    positions = jnp.full((x.shape[0], 1), index, jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, x)
    q = rope(q, positions, cfg.rope_theta)
    k_new = rope(k_new, positions, cfg.rope_theta)

    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, index, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, index, axis=1)

    b, t, h, dh = q.shape
    s = ck.shape[1]
    kpos = jnp.arange(s)
    valid = kpos <= index
    if window and window > 0:
        valid &= kpos > (index - window)
    if DECODE_GROUPED_GQA:
        out = _grouped_decode_attend(cfg, q, ck, cv, valid)
    else:
        ke = _expand_kv(ck, h)
        ve = _expand_kv(cv, h)
        scores = jnp.einsum("bthd,bshd->bths", q, ke) \
            / jnp.sqrt(dh).astype(q.dtype)
        scores = softcap(scores, cfg.attn_softcap)
        scores = jnp.where(valid[None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1
                               ).astype(q.dtype)
        out = jnp.einsum("bths,bshd->bthd", probs, ve)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, {"k": ck, "v": cv}
