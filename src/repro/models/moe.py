"""Mixture-of-Experts FFN (DeepSeekMoE / Llama-4 Maverick families).

Shared experts (always-on, DeepSeekMoE) + routed experts with softmax top-k
gating.  Dispatch uses the capacity-based scatter/gather formulation
(GShard-style): tokens are scattered into per-expert buffers [E, C, d] via
cumsum positions (O(N*k*d) data movement, no N*E*C einsum), the expert
matmuls run as one batched [E, C, d] x [E, d, f] contraction (FLOPs =
top_k * N * d * f * capacity_factor — i.e. the *active* compute only), and
outputs gather back with routing weights.  With the expert axis sharded over
the "model" mesh axis this is expert parallelism; XLA SPMD inserts the
dispatch all-to-all.  Tokens overflowing an expert's capacity are dropped
(standard GShard semantics); an auxiliary Switch-style load-balancing loss
discourages that in training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Array = jnp.ndarray


def init_moe(key, cfg) -> dict:
    m = cfg.moe
    d, de = cfg.d_model, (m.d_expert or cfg.d_ff)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.n_experts), in_axis=0),
        "w_gate": dense_init(ks[1], (m.n_experts, d, de), in_axis=1),
        "w_up": dense_init(ks[2], (m.n_experts, d, de), in_axis=1),
        "w_down": dense_init(ks[3], (m.n_experts, de, d), in_axis=1),
    }
    if m.n_shared:
        sk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "gate": dense_init(sk[0], (d, de * m.n_shared)),
            "up": dense_init(sk[1], (d, de * m.n_shared)),
            "down": dense_init(sk[2], (de * m.n_shared, d)),
        }
    return p


def expert_capacity(n_tokens: int, cfg) -> int:
    m = cfg.moe
    cap = int(n_tokens * m.top_k * cfg.capacity_factor / m.n_experts) + 1
    return max(cap, 4)


DISPATCH_MODE = "sort"   # "sort" (default) | "cumsum" (original baseline)

# Expert-parallel sharding constraint: mesh axis to pin the expert buffers
# to. Without it GSPMD replicates the [E, C, d] buffer and all-reduces it —
# catastrophic at 1M tokens (hillclimb D2/D3: 4.4x on the collective term,
# 3.4x on memory). Default "model"; harmless outside a mesh (guarded), and
# no-op when E doesn't divide the axis.
EP_CONSTRAINT_AXIS: str | None = "model"


def _ep_constrain(x: Array, spec_axes) -> Array:
    if EP_CONSTRAINT_AXIS is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = [EP_CONSTRAINT_AXIS if a == "E" else None for a in spec_axes]
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


def _positions_cumsum(flat_e: Array, n_experts: int) -> Array:
    """Per-(token,slot) rank within its expert via a one-hot cumsum.

    Simple but O(N*E) work on an [N*k, E] intermediate — at 1M-token train
    batches this dominated the compute/memory roofline terms (hillclimb
    Cell D, EXPERIMENTS.md §Perf). Kept as the measured baseline."""
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    return jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]


def _positions_sort(flat_e: Array, n_experts: int) -> Array:
    """Per-(token,slot) rank within its expert via a stable argsort
    (MegaBlocks-style): O(N log N), no [N, E] intermediate. Stability keeps
    the same earlier-token-wins capacity semantics as the cumsum path."""
    nk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(nk, dtype=jnp.int32) - starts[sorted_e]
    return jnp.zeros((nk,), jnp.int32).at[order].set(rank_sorted)


def moe_forward(params, cfg, x: Array) -> tuple[Array, Array]:
    """x: [B, T, D] -> (y, aux_loss)."""
    m = cfg.moe
    b, t, d = x.shape
    n = b * t
    xf = x.reshape(n, d)
    cap = expert_capacity(n, cfg)

    logits = xf @ params["router"]                        # [N, E]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_i = jax.lax.top_k(probs, m.top_k)          # [N, k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    top_w = top_w.astype(xf.dtype)

    flat_e = top_i.reshape(-1)                            # [N*k]
    if DISPATCH_MODE == "sort":
        pos = _positions_sort(flat_e, m.n_experts)
    else:
        pos = _positions_cumsum(flat_e, m.n_experts)
    keep = pos < cap
    # dropped entries alias slot 0 but contribute zeros (masked add), so the
    # buffer stays exactly [E*C, d] — shardable on the expert axis.
    slot = jnp.where(keep, flat_e * cap + jnp.minimum(pos, cap - 1), 0)

    # scatter tokens into expert buffers [E*C, d]
    buf = jnp.zeros((m.n_experts * cap, d), xf.dtype)
    tok_rep = jnp.repeat(jnp.arange(n), m.top_k)
    buf = buf.at[slot].add(xf[tok_rep] * keep[:, None].astype(xf.dtype))
    eb = buf.reshape(m.n_experts, cap, d)
    eb = _ep_constrain(eb, ("E", None, None))

    # --- expert compute (batched over experts) ---
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, params["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", eb, params["w_up"])
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out = _ep_constrain(out, ("E", None, None))

    # --- combine: gather back, weight, and sum over the k slots ---
    out_flat = out.reshape(m.n_experts * cap, d)
    gathered = out_flat[slot] * (top_w.reshape(-1)[:, None]
                                 * keep[:, None].astype(out.dtype))
    y = jnp.sum(gathered.reshape(n, m.top_k, d), axis=1)

    if m.n_shared:
        s = params["shared"]
        y = y + (jax.nn.silu(xf @ s["gate"]) * (xf @ s["up"])) @ s["down"]

    # Switch-style load-balance aux loss
    me = probs.mean(axis=0)                                # mean router prob
    counts = jnp.zeros((m.n_experts,), jnp.float32).at[flat_e].add(1.0)
    frac = counts / n                                      # assignment frac
    aux = jnp.sum(me * frac) * m.n_experts

    return y.reshape(b, t, d), aux.astype(jnp.float32)
