"""Model configuration — one dataclass drives every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    n_shared: int = 0              # always-on shared experts (DeepSeekMoE)
    d_expert: int = 0              # per-expert FFN width
    every_k_layers: int = 1        # MoE every k-th block (Llama-4 interleaves)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                        # 0 -> d_model // n_heads
    # repeating block group, e.g. ("attn",), ("rec","rec","attn_local"),
    # ("mlstm","mlstm","mlstm","slstm"), ("attn_local","attn")
    block_pattern: tuple[str, ...] = ("attn",)
    # FFN kind per pattern position: "dense" | "moe" | "none" (xLSTM blocks
    # carry their own projections). Empty -> auto: "moe" if cfg.moe else
    # "dense" for attn/rec blocks, "none" for mlstm/slstm blocks.
    ffn_pattern: tuple[str, ...] = ()
    # --- attention features ---
    rope_theta: float = 10_000.0
    qk_norm: bool = False                  # Qwen3
    qkv_bias: bool = False                 # Qwen1.5
    attn_softcap: Optional[float] = None   # Gemma-2 (50.0)
    logit_softcap: Optional[float] = None  # Gemma-2 final logits (30.0)
    window: int = 0                        # local-attention window (0 = full)
    # --- FFN / MoE ---
    moe: Optional[MoEConfig] = None
    capacity_factor: float = 1.25          # GShard expert-capacity factor
    first_k_dense: int = 0                 # DeepSeekMoE: first k layers dense
    dense_d_ff: int = 0                    # width of those dense layers
    # --- norm / embeddings ---
    norm_eps: float = 1e-6
    nonparam_norm: bool = False            # OLMo non-parametric LN
    post_norm: bool = False                # Gemma-2 pre+post norm sandwich
    embed_scale: bool = False              # Gemma family scales by sqrt(d)
    tie_embeddings: bool = False
    # --- recurrent blocks ---
    conv_width: int = 4                    # temporal conv (RG-LRU / xLSTM)
    rec_heads: int = 0                     # RG-LRU block heads (0 -> n_heads)
    # --- encoder-decoder (seamless-m4t) ---
    enc_layers: int = 0                    # >0 enables cross-attention decoder
    enc_seq_divisor: int = 4               # encoder frames = seq // divisor
    # --- multimodal frontends (stubs; embeddings arrive as inputs) ---
    vision_tokens: int = 0                 # InternVL patch tokens per sample
    vit_dim: int = 0                       # raw patch-embedding width
    # --- dtypes ---
    param_dtype: str = "float32"
    # --- metadata ---
    family: str = "dense"                  # dense|moe|hybrid|ssm|audio|vlm
    subquadratic: bool = False             # supports long_500k

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Embedding/head tables pad the vocab to a multiple of 256 so the
        vocab axis shards over the model mesh axis (true vocab sizes like
        seamless's 256206 or internvl's 151655 are indivisible — unpadded
        they force replicated [B, T, V] logits). Targets always use true
        vocab ids; the padding rows are inert."""
        return -(-self.vocab // 256) * 256

    @property
    def ffn_kinds(self) -> tuple[str, ...]:
        if self.ffn_pattern:
            return self.ffn_pattern
        out = []
        for b in self.block_pattern:
            if b in ("mlstm", "slstm"):
                out.append("none")
            elif self.moe is not None and self.moe.every_k_layers == 1:
                out.append("moe")
            else:
                out.append("dense")
        return tuple(out)

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def remainder_blocks(self) -> tuple[str, ...]:
        """Blocks beyond the scanned groups (pattern-truncated tail)."""
        rem = self.n_layers - self.n_groups * len(self.block_pattern)
        return self.block_pattern[:rem]

    def validate(self) -> "ModelConfig":
        assert self.n_layers >= len(self.block_pattern) >= 1
        assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if self.moe is not None:
            assert self.moe.top_k <= self.moe.n_experts
        return self

    def scaled_down(self, **overrides) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        pattern = self.block_pattern
        n_layers = max(len(pattern), 2 * len(pattern))
        small = dict(
            d_model=128,
            n_layers=n_layers,
            n_heads=4,
            n_kv_heads=max(1, 4 * self.n_kv_heads // max(self.n_heads, 1)),
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            d_head=32,
            enc_layers=2 if self.enc_layers else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            vit_dim=64 if self.vit_dim else 0,
            first_k_dense=min(self.first_k_dense, 1),
            dense_d_ff=256 if self.dense_d_ff else 0,
        )
        if self.moe is not None:
            small["moe"] = MoEConfig(
                n_experts=4, top_k=min(self.moe.top_k, 2),
                n_shared=min(self.moe.n_shared, 1), d_expert=64,
                every_k_layers=self.moe.every_k_layers)
        small.update(overrides)
        return dataclasses.replace(self, **small).validate()
