"""Shared primitive layers: norms, rotary embeddings, MLPs, inits."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def dense_init(key, shape, in_axis=-2, dtype=jnp.float32) -> Array:
    """LeCun-normal init (fan-in) — standard for transformer stacks."""
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, dtype) / jnp.sqrt(jnp.maximum(fan_in, 1))
            ).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32) -> Array:
    return jax.random.normal(key, shape, dtype) * 0.02


def rms_norm(x: Array, scale: Array | None, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale)
    return y.astype(x.dtype)


def nonparam_layer_norm(x: Array, eps: float = 1e-5) -> Array:
    """OLMo's non-parametric LayerNorm: no scale, no bias."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def norm(cfg, x: Array, scale: Array | None) -> Array:
    if cfg.nonparam_norm:
        return nonparam_layer_norm(x, cfg.norm_eps)
    return rms_norm(x, scale, cfg.norm_eps)


def norm_param(cfg, d: int):
    """None for non-parametric norms, zeros(d) otherwise (RMS 1+scale)."""
    return None if cfg.nonparam_norm else jnp.zeros((d,), jnp.float32)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding. x: [..., T, H, D]; positions: [..., T]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,T,1,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def softcap(x: Array, cap: float | None) -> Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def init_mlp(key, d: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, (d, d_ff), dtype=dtype),
        "up": dense_init(k2, (d, d_ff), dtype=dtype),
        "down": dense_init(k3, (d_ff, d), dtype=dtype),
    }


def mlp(params: dict, x: Array) -> Array:
    return swiglu(x, params["gate"], params["up"], params["down"])
