"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM (scalar
memory with exponential gating).

mLSTM training uses the stabilized parallel form (quadratic in T, like
attention with cumulative log-gates); decode keeps the recurrent state
(C: [B,H,D,D], n: [B,H,D], m: [B,H]) — constant memory in sequence length,
which is what qualifies xlstm-125m for the long_500k shape.

sLSTM has hidden-to-hidden recurrence (block-diagonal per head) and is
inherently sequential: training scans time with `lax.scan`.

Block structure follows the paper: mLSTM block = pre-LN -> up-projection x2
-> (conv -> q,k,v -> mLSTM) * swish(gate branch) -> down-projection;
sLSTM block = pre-LN -> conv -> 4-gate sLSTM -> group-norm -> gated FFN.
d_ff = 0 in the assigned config: all width lives in these projections.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm
from repro.models.rglru import conv1d_causal

Array = jnp.ndarray

PF_MLSTM = 2.0   # mLSTM up-projection factor
PF_SLSTM = 4.0 / 3.0


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm_block(key, cfg) -> dict:
    d = cfg.d_model
    di = int(PF_MLSTM * d)
    h = cfg.n_heads
    dh = di // h
    ks = jax.random.split(key, 9)
    return {
        "w_up": dense_init(ks[0], (d, di)),
        "w_gate": dense_init(ks[1], (d, di)),
        "conv_w": dense_init(ks[2], (cfg.conv_width, di), in_axis=0) * 0.1,
        "conv_b": jnp.zeros((di,), jnp.float32),
        "wq": dense_init(ks[3], (di, h, dh), in_axis=0),
        "wk": dense_init(ks[4], (di, h, dh), in_axis=0),
        "wv": dense_init(ks[5], (di, h, dh), in_axis=0),
        "w_if": dense_init(ks[6], (di, h, 2), in_axis=0),  # input/forget gates
        "b_if": jnp.zeros((h, 2), jnp.float32),
        "skip": jnp.ones((di,), jnp.float32),
        "out_norm": jnp.zeros((di,), jnp.float32),
        "w_down": dense_init(ks[7], (di, d)),
    }


def _mlstm_parallel(q, k, v, log_i, log_f):
    """Stabilized parallel mLSTM. q/k/v: [B,T,H,D]; gates: [B,T,H]."""
    b, t, h, dh = q.shape
    cum_f = jnp.cumsum(log_f, axis=1)                       # [B,T,H]
    # D[t, s] = cum_f[t] - cum_f[s] + log_i[s]  for s <= t
    dmat = cum_f[:, :, None, :] - cum_f[:, None, :, :] \
        + log_i[:, None, :, :]                              # [B,T,S,H]
    mask = jnp.tril(jnp.ones((t, t), bool))
    dmat = jnp.where(mask[None, :, :, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)                # [B,T,1,H]
    w = jnp.exp(dmat - m)                                   # stabilized
    scores = jnp.einsum("bthd,bshd->btsh", q, k) / jnp.sqrt(dh)
    ws = w * scores
    num = jnp.einsum("btsh,bshd->bthd", ws, v)
    den = jnp.maximum(jnp.abs(jnp.sum(ws, axis=2)),
                      jnp.exp(-m[:, :, 0, :]))              # [B,T,H]
    return num / den[..., None]


def mlstm_forward(params, cfg, x: Array, return_state: bool = False):
    up = x @ params["w_up"]
    gate = x @ params["w_gate"]
    c, conv_state = conv1d_causal({"conv_w": params["conv_w"],
                                   "conv_b": params["conv_b"]}, up)
    c = jax.nn.silu(c)
    q = jnp.einsum("btd,dhk->bthk", c, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", c, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", up, params["wv"])
    gif = jnp.einsum("btd,dhg->bthg", up, params["w_if"]) + params["b_if"]
    log_i = gif[..., 0] - jax.nn.softplus(gif[..., 0])      # log sigmoid-ish
    log_f = -jax.nn.softplus(-gif[..., 1])                  # log sigmoid
    hten = _mlstm_parallel(q, k, v, log_i, log_f)
    b, t, h, dh = hten.shape
    hflat = rms_norm(hten.reshape(b, t, h * dh), params["out_norm"])
    hflat = hflat + params["skip"] * c
    y = (hflat * jax.nn.silu(gate)) @ params["w_down"]
    if not return_state:
        return y
    # final recurrent state for decode continuation:
    # m_T = max_s (cumf_T - cumf_s + logi_s); C/n accumulate exp(.-m_T) terms
    cum_f = jnp.cumsum(log_f, axis=1)                        # [B,T,H]
    w_log = cum_f[:, -1:, :] - cum_f + log_i                 # [B,T,H]
    m_t = jnp.max(w_log, axis=1)                             # [B,H]
    w = jnp.exp(w_log - m_t[:, None, :])                     # [B,T,H]
    c_state = jnp.einsum("bth,bthv,bthk->bhvk", w, v, k) / jnp.sqrt(dh)
    n_state = jnp.einsum("bth,bthk->bhk", w, k) / jnp.sqrt(dh)
    state = {"C": c_state, "n": n_state, "m": m_t, "conv": conv_state}
    return y, state


def init_mlstm_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    di = int(PF_MLSTM * cfg.d_model)
    h = cfg.n_heads
    dh = di // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), dtype),
        "n": jnp.zeros((batch, h, dh), dtype),
        "m": jnp.full((batch, h), -1e30, dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), dtype),
    }


def mlstm_decode(params, cfg, x: Array, cache: dict) -> tuple[Array, dict]:
    """x: [B, 1, D]."""
    up = x @ params["w_up"]
    gate = x @ params["w_gate"]
    c, conv_state = conv1d_causal({"conv_w": params["conv_w"],
                                   "conv_b": params["conv_b"]},
                                  up, cache["conv"])
    c = jax.nn.silu(c)
    q = jnp.einsum("btd,dhk->bthk", c, params["wq"])[:, 0]
    k = jnp.einsum("btd,dhk->bthk", c, params["wk"])[:, 0]
    v = jnp.einsum("btd,dhk->bthk", up, params["wv"])[:, 0]
    gif = jnp.einsum("btd,dhg->bthg", up, params["w_if"])[:, 0] + params["b_if"]
    log_i = gif[..., 0] - jax.nn.softplus(gif[..., 0])
    log_f = -jax.nn.softplus(-gif[..., 1])

    m_new = jnp.maximum(cache["m"] + log_f, log_i)          # [B,H]
    fs = jnp.exp(cache["m"] + log_f - m_new)
    is_ = jnp.exp(log_i - m_new)
    dh = q.shape[-1]
    c_new = fs[..., None, None] * cache["C"] \
        + is_[..., None, None] * (v[..., :, None] * k[..., None, :] / jnp.sqrt(dh))
    n_new = fs[..., None] * cache["n"] + is_[..., None] * k / jnp.sqrt(dh)
    num = jnp.einsum("bhvk,bhk->bhv", c_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q)),
                      jnp.exp(-m_new))
    hten = num / den[..., None]                             # [B,H,dh]
    b = x.shape[0]
    hflat = rms_norm(hten.reshape(b, 1, -1), params["out_norm"])
    hflat = hflat + params["skip"] * c
    y = (hflat * jax.nn.silu(gate)) @ params["w_down"]
    return y, {"C": c_new, "n": n_new, "m": m_new, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm_block(key, cfg) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    dff = int(PF_SLSTM * d)
    ks = jax.random.split(key, 8)
    return {
        "conv_w": dense_init(ks[0], (cfg.conv_width, d), in_axis=0) * 0.1,
        "conv_b": jnp.zeros((d,), jnp.float32),
        "w_gates": dense_init(ks[1], (d, h, 4, dh), in_axis=0),  # z i f o
        "r_gates": dense_init(ks[2], (h, 4, dh, dh), in_axis=2) * 0.1,
        "b_gates": jnp.zeros((h, 4, dh), jnp.float32),
        "out_norm": jnp.zeros((d,), jnp.float32),
        "ff_gate": dense_init(ks[3], (d, dff)),
        "ff_up": dense_init(ks[4], (d, dff)),
        "ff_down": dense_init(ks[5], (dff, d)),
    }


def _slstm_step(params, carry, xg):
    """carry: (c, n, h, m) each [B, H, dh]; xg: [B, H, 4, dh]."""
    c, n, hprev, m = carry
    rec = jnp.einsum("bhd,hgde->bhge", hprev, params["r_gates"])
    g = xg + rec + params["b_gates"]
    z = jnp.tanh(g[:, :, 0])
    i_ = g[:, :, 1]
    f_ = g[:, :, 2]
    o = jax.nn.sigmoid(g[:, :, 3])
    log_f = -jax.nn.softplus(-f_)
    m_new = jnp.maximum(log_f + m, i_)
    i_s = jnp.exp(i_ - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = jnp.maximum(f_s * n + i_s, 1e-6)
    h_new = o * (c_new / n_new)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_forward(params, cfg, x: Array, return_state: bool = False):
    b, t, d = x.shape
    h, dh = cfg.n_heads, d // cfg.n_heads
    u, conv_state = conv1d_causal({"conv_w": params["conv_w"],
                                   "conv_b": params["conv_b"]}, x)
    u = jax.nn.silu(u)
    xg = jnp.einsum("btd,dhge->bthge", u, params["w_gates"])  # [B,T,H,4,dh]
    carry = (jnp.zeros((b, h, dh), x.dtype), jnp.full((b, h, dh), 1e-6, x.dtype),
             jnp.zeros((b, h, dh), x.dtype), jnp.full((b, h, dh), -1e30, x.dtype))
    step = lambda c, xt: _slstm_step(params, c, xt)
    carry, hs = jax.lax.scan(step, carry, jnp.swapaxes(xg, 0, 1))
    hs = jnp.swapaxes(hs, 0, 1).reshape(b, t, d)
    hs = rms_norm(hs, params["out_norm"], cfg.norm_eps)
    y = (jax.nn.silu(hs @ params["ff_gate"]) * (hs @ params["ff_up"])) \
        @ params["ff_down"]
    if return_state:
        cc, nn, hh, mm = carry
        return y, {"c": cc, "n": nn, "h": hh, "m": mm, "conv": conv_state}
    return y


def init_slstm_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    return {
        "c": jnp.zeros((batch, h, dh), dtype),
        "n": jnp.full((batch, h, dh), 1e-6, dtype),
        "h": jnp.zeros((batch, h, dh), dtype),
        "m": jnp.full((batch, h, dh), -1e30, dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d), dtype),
    }


def slstm_decode(params, cfg, x: Array, cache: dict) -> tuple[Array, dict]:
    b, _, d = x.shape
    u, conv_state = conv1d_causal({"conv_w": params["conv_w"],
                                   "conv_b": params["conv_b"]},
                                  x, cache["conv"])
    u = jax.nn.silu(u)
    xg = jnp.einsum("btd,dhge->bthge", u, params["w_gates"])[:, 0]
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    (c, n, hh, m), h_new = _slstm_step(params, carry, xg)
    hs = h_new.reshape(b, 1, d)
    hs = rms_norm(hs, params["out_norm"], cfg.norm_eps)
    y = (jax.nn.silu(hs @ params["ff_gate"]) * (hs @ params["ff_up"])) \
        @ params["ff_down"]
    return y, {"c": c, "n": n, "h": hh, "m": m, "conv": conv_state}
