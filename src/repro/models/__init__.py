"""models — the 10 assigned architectures as composable pure-JAX modules.

A single config-driven stack (`transformer.py`) covers the dense / MoE /
hybrid-recurrent / xLSTM decoder families via a repeating ``block_pattern``;
`encdec.py` wraps it for encoder-decoder (seamless-m4t); modality frontends
(audio frames, ViT patches) are stubs per the brief — `input_specs()` feeds
precomputed embeddings.

All parameters are plain pytrees (nested dicts); `init_params` is pure (and
therefore usable under `jax.eval_shape` for the dry-run without allocating
the 400B-parameter configs).
"""

from repro.models.config import ModelConfig, MoEConfig
from repro.models.transformer import (
    init_params,
    forward,
    init_cache,
    decode_step,
    param_count,
    active_param_count,
)

__all__ = [
    "ModelConfig", "MoEConfig", "init_params", "forward", "init_cache",
    "decode_step", "param_count", "active_param_count",
]
