"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence (diagonal, gated):
    r_t = sigmoid(W_a x_t)                    (recurrence gate)
    i_t = sigmoid(W_x x_t)                    (input gate)
    a_t = exp(-c * softplus(L) * r_t)         (c = 8, L learned)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Block: two branches from the residual stream — a gelu-gated linear branch
and (temporal conv(width 4) -> RG-LRU) — multiplied and projected out.

Training path: `scan_rg_lru` — an associative scan (the `ref.py` oracle for
the Pallas `rg_lru` kernel, which tiles (batch, channel) blocks in VMEM and
walks time sequentially).  Decode path: single fused step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Array = jnp.ndarray

_C = 8.0


def init_rglru_block(key, cfg) -> dict:
    d = cfg.d_model
    dr = d  # lru width = d_model in RecurrentGemma
    ks = jax.random.split(key, 7)
    return {
        "w_lin": dense_init(ks[0], (d, dr)),        # gelu branch
        "w_x": dense_init(ks[1], (d, dr)),          # recurrent branch in
        "w_out": dense_init(ks[2], (dr, d)),
        "conv_w": dense_init(ks[3], (cfg.conv_width, dr), in_axis=0) * 0.1,
        "conv_b": jnp.zeros((dr,), jnp.float32),
        "w_a": dense_init(ks[4], (dr, dr)),
        "w_i": dense_init(ks[5], (dr, dr)),
        # softplus(L) in (0.999, 0.001)-ish decay band at init
        "lam": jax.random.uniform(ks[6], (dr,), jnp.float32, 0.2, 0.8),
    }


def _gates(params, u: Array):
    r = jax.nn.sigmoid(u @ params["w_a"])
    i = jax.nn.sigmoid(u @ params["w_i"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u)
    return a, gated


def scan_rg_lru(a: Array, b: Array, h0: Array | None = None) -> Array:
    """h_t = a_t * h_{t-1} + b_t along axis 1. a/b: [B, T, D]."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def conv1d_causal(params, x: Array, state: Array | None = None):
    """Depthwise causal temporal conv. x: [B, T, D]; state: [B, W-1, D]."""
    w = params["conv_w"]                      # [W, D]
    width = w.shape[0]
    pad = (jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
           if state is None else state)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else pad
    return out + params["conv_b"], new_state


def rglru_forward(params, cfg, x: Array, use_kernel: bool = False,
                  return_state: bool = False):
    """Full-sequence recurrent block. x: [B, T, D]."""
    lin = jax.nn.gelu(x @ params["w_lin"])
    u_raw = x @ params["w_x"]
    u, conv_state = conv1d_causal(params, u_raw)
    a, b = _gates(params, u)
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        h = kernel_ops.rg_lru(a, b)
    else:
        h = scan_rg_lru(a, b)
    y = (h * lin) @ params["w_out"]
    if return_state:
        return y, {"h": h[:, -1], "conv": conv_state}
    return y


def init_rglru_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    return {
        "h": jnp.zeros((batch, d), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d), dtype),
    }


def rglru_decode(params, cfg, x: Array, cache: dict) -> tuple[Array, dict]:
    """Single-token step. x: [B, 1, D]."""
    lin = jax.nn.gelu(x @ params["w_lin"])
    u = x @ params["w_x"]
    u, conv_state = conv1d_causal(params, u, cache["conv"])
    a, b = _gates(params, u)
    h = a[:, 0] * cache["h"] + b[:, 0]
    y = (h[:, None] * lin) @ params["w_out"]
    return y, {"h": h, "conv": conv_state}
