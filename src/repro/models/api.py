"""Unified model API over the decoder-only and encoder-decoder stacks.

A batch is a dict:
  tokens   [B, T] int32            (always)
  frames   [B, T_enc, d] float     (audio family: stub frontend embeddings)
  patches  [B, n_vision, vit_dim]  (vlm family: stub patch embeddings)
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.config import ModelConfig

Array = jnp.ndarray


def is_encdec(cfg: ModelConfig) -> bool:
    return cfg.enc_layers > 0


def init_params(cfg: ModelConfig, key) -> Any:
    if is_encdec(cfg):
        return encdec.init_params(cfg, key)
    return transformer.init_params(cfg, key)


def forward(cfg: ModelConfig, params, batch: dict, use_kernel: bool = False,
            remat: bool = True, unroll: bool = False) -> tuple[Array, Array]:
    if is_encdec(cfg):
        return encdec.forward(cfg, params, batch["tokens"], batch["frames"],
                              use_kernel=use_kernel, unroll=unroll)
    return transformer.forward(cfg, params, batch["tokens"],
                               extra_embeds=batch.get("patches"),
                               use_kernel=use_kernel, remat=remat,
                               unroll=unroll)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32, enc_len: int = 0) -> dict:
    if is_encdec(cfg):
        return encdec.init_cache(cfg, batch, max_len,
                                 enc_len or max(max_len // cfg.enc_seq_divisor, 8),
                                 dtype)
    return transformer.init_cache(cfg, batch, max_len, dtype)


def decode_step(cfg: ModelConfig, params, cache: dict, token: Array,
                index, unroll: bool = False) -> tuple[Array, dict]:
    if is_encdec(cfg):
        return encdec.decode_step(cfg, params, cache, token, index,
                                  unroll=unroll)
    return transformer.decode_step(cfg, params, cache, token, index,
                                   unroll=unroll)


def prefill(cfg: ModelConfig, params, batch: dict, max_len: int,
            use_kernel: bool = False, unroll: bool = False
            ) -> tuple[Array, dict]:
    if is_encdec(cfg):
        return encdec.prefill(cfg, params, batch["tokens"], batch["frames"],
                              max_len, use_kernel=use_kernel, unroll=unroll)
    return transformer.prefill(cfg, params, batch["tokens"], max_len,
                               extra_embeds=batch.get("patches"),
                               use_kernel=use_kernel, unroll=unroll)
