"""Encoder-decoder wrapper (seamless-m4t family).

Encoder: bidirectional attention stack over precomputed modality frame
embeddings (the speech frontend is a stub per the brief — `input_specs`
supplies [B, T_enc, d] frames).  Decoder: causal self-attention +
cross-attention + FFN blocks over target tokens.  Both stacks scan their
layers like `transformer.py`.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention, layers
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, embed_init, norm, norm_param

Array = jnp.ndarray
Params = Any


def _init_enc_block(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": norm_param(cfg, cfg.d_model),
        "attn": attention.init_attn(k1, cfg),
        "norm2": norm_param(cfg, cfg.d_model),
        "ffn": layers.init_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def _init_dec_block(key, cfg) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": norm_param(cfg, cfg.d_model),
        "self_attn": attention.init_attn(k1, cfg),
        "norm_x": norm_param(cfg, cfg.d_model),
        "cross_attn": attention.init_attn(k2, cfg),
        "norm2": norm_param(cfg, cfg.d_model),
        "ffn": layers.init_mlp(k3, cfg.d_model, cfg.d_ff),
    }


def init_params(cfg: ModelConfig, key: Array) -> Params:
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    enc = [_init_enc_block(k, cfg) for k in enc_keys]
    dec = [_init_dec_block(k, cfg) for k in dec_keys]
    return {
        "embed": embed_init(ks[2], (cfg.vocab_padded, cfg.d_model)),
        "enc": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "enc_norm": norm_param(cfg, cfg.d_model),
        "dec": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "final_norm": norm_param(cfg, cfg.d_model),
        "head": dense_init(ks[3], (cfg.d_model, cfg.vocab_padded)),
    }


def _maybe_unrolled_scan(fn, carry, xs, unroll):
    if not unroll:
        return jax.lax.scan(fn, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = fn(carry, jax.tree.map(lambda x: x[i], xs))
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    return carry, jax.tree.map(lambda *zs: jnp.stack(zs), *ys)


def encode(cfg: ModelConfig, params: Params, frames: Array,
           use_kernel: bool = False, unroll: bool = False) -> Array:
    """frames: [B, T_enc, d] precomputed frontend embeddings."""
    frames = frames.astype(params["embed"].dtype)   # match compute dtype
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1]), frames.shape[:2])

    def block(h, p):
        x = norm(cfg, h, p["norm1"])
        h = h + attention.attn_forward(p["attn"], cfg, x, positions=positions,
                                       causal=False, use_kernel=use_kernel)
        x = norm(cfg, h, p["norm2"])
        return h + layers.mlp(p["ffn"], x), None

    h, _ = _maybe_unrolled_scan(jax.checkpoint(block), frames,
                                params["enc"], unroll)
    return norm(cfg, h, params["enc_norm"])


def forward(cfg: ModelConfig, params: Params, tokens: Array,
            enc_frames: Array, use_kernel: bool = False,
            unroll: bool = False) -> tuple[Array, Array]:
    """Teacher-forced training forward. Returns (logits, aux=0)."""
    memory = encode(cfg, params, enc_frames, use_kernel, unroll)
    h = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(h.shape[1]), h.shape[:2])

    def block(h, p):
        x = norm(cfg, h, p["norm1"])
        h = h + attention.attn_forward(p["self_attn"], cfg, x,
                                       positions=positions,
                                       use_kernel=use_kernel)
        x = norm(cfg, h, p["norm_x"])
        h = h + attention.attn_forward(p["cross_attn"], cfg, x,
                                       positions=positions, kv_x=memory)
        x = norm(cfg, h, p["norm2"])
        return h + layers.mlp(p["ffn"], x), None

    h, _ = _maybe_unrolled_scan(jax.checkpoint(block), h, params["dec"],
                                unroll)
    h = norm(cfg, h, params["final_norm"])
    return h @ params["head"], jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int,
               dtype=jnp.float32) -> dict:
    kh, dh = cfg.n_kv_heads, cfg.head_dim
    L = cfg.n_layers
    return {
        "self": {
            "k": jnp.zeros((L, batch, max_len, kh, dh), dtype),
            "v": jnp.zeros((L, batch, max_len, kh, dh), dtype),
        },
        # cross K/V are precomputed from the encoder memory at prefill
        "cross": {
            "k": jnp.zeros((L, batch, enc_len, kh, dh), dtype),
            "v": jnp.zeros((L, batch, enc_len, kh, dh), dtype),
        },
    }


def prefill_cross(cfg: ModelConfig, params: Params, memory: Array,
                  cache: dict) -> dict:
    def per_layer(p):
        k = jnp.einsum("bsd,dhk->bshk", memory, p["cross_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", memory, p["cross_attn"]["wv"])
        if cfg.qkv_bias:
            k, v = k + p["cross_attn"]["bk"], v + p["cross_attn"]["bv"]
        return k, v

    ks, vs = jax.vmap(per_layer)(params["dec"])
    return {**cache, "cross": {"k": ks, "v": vs}}


def prefill(cfg: ModelConfig, params: Params, tokens: Array, frames: Array,
            max_len: int, use_kernel: bool = False,
            unroll: bool = False) -> tuple[Array, dict]:
    """Encode the source, teacher-force the target prefix, emit caches."""
    memory = encode(cfg, params, frames, use_kernel, unroll)
    h = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(h.shape[1]), h.shape[:2])
    batch = h.shape[0]

    def block(h, p):
        x = norm(cfg, h, p["norm1"])
        y, (k, v) = attention.attn_forward(p["self_attn"], cfg, x,
                                           positions=positions,
                                           use_kernel=use_kernel,
                                           return_kv=True)
        h = h + y
        kv = attention.fill_kv_cache(
            attention.init_kv_cache(cfg, batch, max_len, h.dtype), k, v)
        x = norm(cfg, h, p["norm_x"])
        h = h + attention.attn_forward(p["cross_attn"], cfg, x,
                                       positions=positions, kv_x=memory)
        x = norm(cfg, h, p["norm2"])
        return h + layers.mlp(p["ffn"], x), (kv["k"], kv["v"])

    h, (sk, sv) = _maybe_unrolled_scan(block, h, params["dec"], unroll)
    h = norm(cfg, h, params["final_norm"])
    logits = h[:, -1] @ params["head"]
    cache = {"self": {"k": sk, "v": sv}}
    cache = prefill_cross(cfg, params, memory,
                          {**cache, "cross": {"k": None, "v": None}})
    return logits, cache


def decode_step(cfg: ModelConfig, params: Params, cache: dict, token: Array,
                index: Array, unroll: bool = False) -> tuple[Array, dict]:
    h = params["embed"][token][:, None, :]

    def block(carry, xs):
        h = carry
        p, sk, sv, ck, cv = xs
        x = norm(cfg, h, p["norm1"])
        y, new_self = attention.attn_decode(p["self_attn"], cfg, x,
                                            {"k": sk, "v": sv}, index)
        h = h + y
        # cross attention against the precomputed memory K/V (no mask)
        x = norm(cfg, h, p["norm_x"])
        q = jnp.einsum("btd,dhk->bthk", x, p["cross_attn"]["wq"])
        if cfg.qkv_bias:
            q = q + p["cross_attn"]["bq"]
        dh = q.shape[-1]
        ke = attention._expand_kv(ck, q.shape[2])
        ve = attention._expand_kv(cv, q.shape[2])
        sc = jnp.einsum("bthd,bshd->bths", q, ke) / jnp.sqrt(dh)
        pr = jax.nn.softmax(sc.astype(jnp.float32), -1).astype(q.dtype)
        o = jnp.einsum("bths,bshd->bthd", pr, ve)
        h = h + jnp.einsum("bthk,hkd->btd", o, p["cross_attn"]["wo"])
        x = norm(cfg, h, p["norm2"])
        h = h + layers.mlp(p["ffn"], x)
        return h, (new_self["k"], new_self["v"])

    xs = (params["dec"], cache["self"]["k"], cache["self"]["v"],
          cache["cross"]["k"], cache["cross"]["v"])
    h, (nk, nv) = _maybe_unrolled_scan(block, h, xs, unroll)
    h = norm(cfg, h, params["final_norm"])
    logits = h[:, 0] @ params["head"]
    return logits, {"self": {"k": nk, "v": nv}, "cross": cache["cross"]}
