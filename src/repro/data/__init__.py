"""data — deterministic, host-shardable synthetic token pipeline."""

from repro.data.pipeline import DataConfig, make_batch_iterator, synthetic_batch

__all__ = ["DataConfig", "make_batch_iterator", "synthetic_batch"]
