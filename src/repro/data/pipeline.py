"""Synthetic-but-structured token pipeline.

Deterministic per (seed, step, host): every host materializes only its shard
of the global batch (`host_id`/`n_hosts`), so the same pipeline code drives
the 1-device CPU smoke tests and a 512-chip launch.  The stream is a mixture
of Zipf-distributed unigrams and short copied motifs, which gives a model a
learnable signal (loss decreases measurably within a few hundred steps —
used by examples/quickstart.py).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    # modality extras (stub frontends)
    frames: int = 0
    frame_dim: int = 0
    vision_tokens: int = 0
    vit_dim: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def _zipf_motif_tokens(rng: np.random.Generator, b: int, t: int,
                       vocab: int) -> np.ndarray:
    # Zipf unigrams
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    toks = rng.choice(vocab, size=(b, t), p=probs)
    # splice short copied motifs (predictable structure => learnable)
    for i in range(b):
        motif_len = int(rng.integers(4, 12))
        motif = rng.choice(vocab, size=motif_len)
        reps = max(1, t // (motif_len * 4))
        for r in range(reps):
            start = int(rng.integers(0, max(t - motif_len, 1)))
            toks[i, start: start + motif_len] = motif[: t - start]
    return toks.astype(np.int32)


def synthetic_batch(cfg: DataConfig, step: int) -> dict:
    """One host-local batch for ``step`` (pure function of cfg+step)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
    b = cfg.host_batch
    batch = {"tokens": jnp.asarray(
        _zipf_motif_tokens(rng, b, cfg.seq_len, cfg.vocab))}
    if cfg.frames:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.frames, cfg.frame_dim),
                                dtype=np.float32))
    if cfg.vision_tokens:
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.vision_tokens, cfg.vit_dim),
                                dtype=np.float32))
    return batch


def make_batch_iterator(cfg: DataConfig, start_step: int = 0,
                        prefetch: int = 2) -> Iterator[dict]:
    """Iterator with simple lookahead prefetch (device_put happens lazily)."""
    import collections
    queue: collections.deque = collections.deque()
    step = start_step
    while True:
        while len(queue) < prefetch + 1:
            queue.append(synthetic_batch(cfg, step))
            step += 1
        yield queue.popleft()
