"""repro.analysis — the five-layer static verifier.

Proves, before anything executes: the fused Pallas CC-tick kernel is in
every lowering that claims it (IR lint), every compile-group split is
explained and the prediction matches what the jit cache actually traces
(plan lint), the sources are free of the bug patterns that break
tracing — np-in-scan, concretized tracers, f64 leaks, unit-suffix
mixups, stale pragmas (source lint), the kernel *body* honors its
memory-space / block / grid / elementwise-f32 invariants per
specialization (kernel lint), and every compile group's
flop/byte/memory/collective envelope matches the committed baseline
(HLO budgets).  One report, one CLI::

    PYTHONPATH=src python -m repro.analysis --ci --profile ci

Severity profiles (``ci`` / ``bench`` / ``notebook``) re-weight the same
rule catalog per consumer — CI gates strictly, notebooks get advisories.
See DESIGN.md §7 for the architecture and §9 for the kernel/budget
layers, the budget schema and the profile semantics.
"""
from repro.analysis.findings import (AnalysisReport, Finding, PROFILES,
                                     Rule, RULES, make_finding,
                                     severity_for)
from repro.analysis.hlo_budget import (BudgetBook, DEFAULT_TOLERANCES,
                                       env_fingerprint, measure_group)
from repro.analysis.jaxpr_lint import (kernel_expectation, lint_closed_jaxpr,
                                       lint_sweep)
from repro.analysis.kernel_lint import (find_kernel_eqns, lint_kernel,
                                        lint_kernel_eqn)
from repro.analysis.plan_lint import (lint_plan, predict_compile_groups,
                                      STRUCTURAL_FIELDS)
from repro.analysis.plans import CI_PLANS, PLANS, resolve_entry
from repro.analysis.runner import analyze_plan, run_analysis
from repro.analysis.source_lint import lint_paths, lint_sources

__all__ = [
    "AnalysisReport", "Finding", "PROFILES", "Rule", "RULES",
    "make_finding", "severity_for",
    "BudgetBook", "DEFAULT_TOLERANCES", "env_fingerprint", "measure_group",
    "kernel_expectation", "lint_closed_jaxpr", "lint_sweep",
    "find_kernel_eqns", "lint_kernel", "lint_kernel_eqn",
    "lint_plan", "predict_compile_groups", "STRUCTURAL_FIELDS",
    "CI_PLANS", "PLANS", "resolve_entry",
    "analyze_plan", "run_analysis",
    "lint_paths", "lint_sources",
]
