"""repro.analysis — the three-layer static verifier.

Proves, before anything executes: the fused Pallas CC-tick kernel is in
every lowering that claims it (IR lint), every compile-group split is
explained and the prediction matches what the jit cache actually traces
(plan lint), and the sources are free of the bug patterns that break
tracing — np-in-scan, concretized tracers, f64 leaks, unit-suffix mixups
(source lint).  One report, one CLI::

    PYTHONPATH=src python -m repro.analysis --ci --plan fig12

See DESIGN.md §7 for the architecture and the full rule catalog.
"""
from repro.analysis.findings import (AnalysisReport, Finding, Rule, RULES,
                                     make_finding)
from repro.analysis.jaxpr_lint import (kernel_expectation, lint_closed_jaxpr,
                                       lint_sweep)
from repro.analysis.plan_lint import (lint_plan, predict_compile_groups,
                                      STRUCTURAL_FIELDS)
from repro.analysis.plans import CI_PLANS, PLANS, resolve_entry
from repro.analysis.runner import analyze_plan, run_analysis
from repro.analysis.source_lint import lint_paths, lint_sources

__all__ = [
    "AnalysisReport", "Finding", "Rule", "RULES", "make_finding",
    "kernel_expectation", "lint_closed_jaxpr", "lint_sweep",
    "lint_plan", "predict_compile_groups", "STRUCTURAL_FIELDS",
    "CI_PLANS", "PLANS", "resolve_entry",
    "analyze_plan", "run_analysis",
    "lint_paths", "lint_sources",
]
