"""Finding records and the analysis report — the one output surface all
five lint layers (IR, plan, source, kernel, budget) emit into.

A `Finding` is a structured diagnostic: a rule id (``layer/rule-name``), a
severity, a location string (``file:line`` for source findings, a
``plan/group`` label for IR, plan, kernel and budget findings) and a human
message.  The `AnalysisReport` aggregates findings plus per-plan *proofs*
— the positive facts the verifier established (kernel present in N
groups, groups predicted == groups traced, zero f64 ops, cost envelopes
within budget) — and renders both; ``--ci`` exits nonzero iff any
error-severity finding survives.

**Severity profiles** (DESIGN.md §9): the same rule catalog serves three
consumers with different stakes.  ``severity_for(rule, profile)`` resolves
a rule's severity under a named profile — ``ci`` (the gate: suppressions
and baselines must be live, so stale-pragma / missing-baseline promote to
error), ``bench`` (the defaults: benchmarks record findings into health
blocks but should not abort a measurement run), ``notebook`` (advisory:
every error demotes to warning, nothing gates).  Per-rule overrides live
on the `Rule` itself; the notebook demotion is the profile-wide fallback.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["Severity", "Finding", "Rule", "AnalysisReport", "RULES",
           "PROFILES", "rule", "make_finding", "severity_for"]

# Severity order (render sorts errors first).
ERROR = "error"
WARNING = "warning"
INFO = "info"
Severity = str
_SEV_ORDER = {ERROR: 0, WARNING: 1, INFO: 2}

# Consumer profiles, strictest first.  "bench" is the default: rule
# severities apply as declared.
PROFILES = ("ci", "bench", "notebook")


@dataclasses.dataclass(frozen=True)
class Rule:
    """One catalog entry: what a rule proves and why it matters."""

    id: str                 # "ir/f64-promotion"
    severity: Severity      # default severity of its findings
    summary: str            # one line, shown in renders
    rationale: str          # why violating it invalidates results
    # per-profile severity overrides as ((profile, severity), ...) pairs
    # (a tuple keeps the dataclass frozen/hashable)
    profiles: tuple = ()

    @property
    def layer(self) -> str:
        return self.id.split("/", 1)[0]

    def severity_in(self, profile: Optional[str]) -> Severity:
        """Effective severity under a named profile (None = declared)."""
        if profile is None or profile == "bench":
            return self.severity
        over = dict(self.profiles)
        if profile in over:
            return over[profile]
        if profile == "notebook" and self.severity == ERROR:
            return WARNING          # advisory: nothing gates a notebook
        return self.severity


# The full rule catalog.  DESIGN.md §7/§9 document each entry; tests
# assert every rule here fires on a deliberately-broken fixture.
RULES: dict[str, Rule] = {}


def rule(id: str, severity: Severity, summary: str, rationale: str,
         profiles: tuple = ()) -> Rule:
    for prof, sev in profiles:
        if prof not in PROFILES or sev not in _SEV_ORDER:
            raise ValueError(f"bad profile override {(prof, sev)!r} on {id}")
    r = Rule(id=id, severity=severity, summary=summary, rationale=rationale,
             profiles=profiles)
    if id in RULES:
        raise ValueError(f"duplicate rule id {id!r}")
    RULES[id] = r
    return r


def severity_for(rule_id: str, profile: Optional[str] = None) -> Severity:
    """A rule's effective severity under a profile (None = declared)."""
    if profile is not None and profile not in PROFILES:
        raise KeyError(f"unknown profile {profile!r}; known: {PROFILES}")
    return RULES[rule_id].severity_in(profile)


# --- IR layer -------------------------------------------------------------
rule("ir/kernel-missing", ERROR,
     "fused CC-tick kernel absent from a kernel-enabled lowering",
     "use_pallas_kernel=True must place the Pallas mltcp_cc_tick "
     "pallas_call inside the tick scan; its absence means the sweep runs "
     "the jnp oracle (perf claims about the fused path are void).")
rule("ir/kernel-fallback", ERROR,
     "config statically forces the kernel->oracle fallback",
     "non-default favoritism / non-linear F are outside the kernel's "
     "specialization; requesting use_pallas_kernel for such a config "
     "can only ever run unfused — fix the config or drop the flag.")
rule("ir/kernel-unexpected", WARNING,
     "pallas_call present in a kernel-disabled lowering",
     "a program that was asked for the jnp oracle must not dispatch the "
     "kernel; oracle-vs-kernel bit-equality checks depend on it.")
rule("ir/f64-promotion", ERROR,
     "float64 value or convert_element_type to f64 in the lowered program",
     "the engine and kernel are pinned bit-stable in f32; a silent f64 "
     "promotion (e.g. under jax_enable_x64) breaks kernel/oracle "
     "bit-equality and doubles memory traffic.")
rule("ir/host-callback", ERROR,
     "host callback / debug print / io callback in the hot path",
     "callbacks inside the tick scan force device->host syncs every "
     "iteration — timing figures measured with one in place are invalid.")
rule("ir/nested-control", ERROR,
     "non-whitelisted while/cond inside the tick-scan body",
     "the tick body is straight-line vectorized math; a stray lax.cond / "
     "while_loop usually means a python branch escaped tracing and will "
     "serialize the vmapped sweep.")

# --- plan layer -----------------------------------------------------------
rule("plan/group-split", INFO,
     "two plan points compile separately (group-split explainer)",
     "every extra compile group is an extra trace+compile; the explainer "
     "names the exact canonicalized fields that differ so splits are "
     "always accounted for.")
rule("plan/avoidable-split", WARNING,
     "compile-group split on value-only fields",
     "the differing fields are plain numeric values that could ride the "
     "batched sweep as traced SweepParams leaves (the PR-4 pattern); the "
     "split wastes traces.")
rule("plan/group-mismatch", ERROR,
     "predicted compile groups != programs actually traced",
     "grouping canonicalization and the jit static signature disagree — "
     "either the canonicalizer merges points the jit cache splits "
     "(silent retraces) or vice versa.")
rule("plan/retrace", ERROR,
     "re-tracing an already-traced compile group",
     "a warm group must hit the jaxpr cache; a retrace means something "
     "unhashable or dynamic leaked into the static config signature.")

# --- source layer ---------------------------------------------------------
rule("src/np-in-scan", ERROR,
     "numpy call in a function reachable from a scan body",
     "np.* inside traced code either fails under vmap/jit or silently "
     "constant-folds per trace; scan bodies must be pure jnp. "
     "Trace-time constants on static shapes may be whitelisted inline.")
rule("src/float-cast-traced", ERROR,
     "python float()/int()/bool() applied to a traced value",
     "concretizing a tracer raises under jit, or — worse — bakes a "
     "trace-time constant into the program so sweeps silently reuse the "
     "first point's value.")
rule("src/branch-on-traced", ERROR,
     "python `if` on a traced value inside traced code",
     "python control flow on tracers raises ConcretizationTypeError "
     "under jit; use jnp.where / lax.cond.")
rule("src/f64-literal", ERROR,
     "float64 literal outside NumPy-side config plumbing",
     "jnp.float64 / astype('float64') in traced code promotes the "
     "bit-stable f32 pipeline; np.float64 is fine only in numpy-side "
     "config plumbing (JobSpec.simple style) that never reaches a scan.")
rule("src/unit-suffix", ERROR,
     "add/subtract/compare across conflicting unit suffixes",
     "names suffixed _bytes/_s/_bps/_ticks carry units; summing or "
     "comparing across units (without a converting multiply/divide) is "
     "the classic silent protocol-parameter bug the RoCE CC sensitivity "
     "studies warn about.")
rule("src/stale-pragma", WARNING,
     "`# lint: allow(rule)` pragma that no longer suppresses anything",
     "a suppression must not outlive the code it excused: a pragma naming "
     "an unknown rule, or a rule that no longer fires on its line, is dead "
     "weight that will silently swallow the next real finding there.",
     profiles=(("ci", ERROR),))

# --- kernel layer (the Pallas CC-tick kernel body; DESIGN.md §9) ----------
rule("kernel/dyn-not-smem", ERROR,
     "DynamicParams operand is not an SMEM scalar vector",
     "the protocol scalars must ride as an f32[NDYN] SMEM ref: a VMEM (or "
     "missing) dyn operand means every grid step re-streams scalars "
     "through the vector path and the operand layout no longer matches "
     "ops.py's packing contract.")
rule("kernel/dyn-written", ERROR,
     "kernel body writes to the DynamicParams SMEM operand",
     "the dyn ref is read-only by contract — a store would make sweep "
     "points order-dependent (one point's protocol scalars leaking into "
     "the next grid step) and breaks the kernel/oracle bit-equality pin.")
rule("kernel/state-not-vmem", ERROR,
     "flow-state operand lives outside VMEM",
     "the perf claim is one HBM read per state array per tick with the "
     "working set VMEM-resident; an SMEM/HBM-pinned state ref silently "
     "serializes the vector loads the roofline model assumes.")
rule("kernel/block-misaligned", ERROR,
     "state block shape is not the (SUBLANES, LANES)-aligned tile",
     "blocks must tile (<=8, 128) exactly as ops.py packs [rows, 128] "
     "lanes; any other shape pads or splits vector registers and the "
     "static VMEM estimate (and the TPU lowering) no longer holds.")
rule("kernel/grid-remainder", ERROR,
     "grid does not cover exactly `rows` blocks",
     "ops.py pads flows so rows % block_rows == 0; a remainder grid step "
     "would need masking the kernel body does not implement — out-of-"
     "bounds lanes would read garbage and corrupt the padded flows.")
rule("kernel/operand-mismatch", ERROR,
     "kernel operand/result count differs from the specialization",
     "the (algo, variant, factors) specialization fixes the operand list "
     "(dyn + optional factors + IN_ORDER) and the result list (OUT_ORDER); "
     "a mismatch means ops.py's packing and the traced kernel disagree — "
     "state arrays are being dropped or duplicated.")
rule("kernel/f64-in-body", ERROR,
     "float64 value inside the kernel body",
     "the kernel is pinned elementwise f32 (bit-equal to the jnp oracle); "
     "an f64 intermediate doubles VMEM pressure and silently changes "
     "rounding versus the oracle.")
rule("kernel/gather-scatter", ERROR,
     "gather/scatter primitive inside the kernel body",
     "every body op must be elementwise over the [block, 128] tile; a "
     "gather or scatter breaks the one-pass VMEM-resident property and "
     "lowers to serialized memory traffic on TPU.")
rule("kernel/nested-control", ERROR,
     "while/cond/scan inside the kernel body",
     "algorithm and variant are static specialization parameters — the "
     "body must be straight-line; traced control flow means a python "
     "branch escaped specialization and will serialize the grid.")
rule("kernel/vmem-budget", ERROR,
     "static VMEM estimate per grid step exceeds the ceiling",
     "the kernel's whole working set (all in/out blocks) must fit VMEM "
     "with room for double buffering; exceeding the ceiling means the "
     "compiler will spill to HBM and the fused-tick perf claim is void.")

# --- budget layer (per-compile-group HLO cost envelopes) ------------------
rule("budget/drift", ERROR,
     "compile-group cost metric drifted beyond tolerance vs the baseline",
     "flops / HBM bytes / peak memory / collective bytes per compile "
     "group are pinned in analysis/budgets.json; unexplained drift means "
     "a change altered what the hot loop costs — either fix it or "
     "re-baseline deliberately via --update-budgets.")
rule("budget/missing-baseline", WARNING,
     "compile group has no baseline entry in budgets.json",
     "an unpinned group's cost can regress silently; record it with "
     "`python -m repro.analysis --update-budgets` (plans or groups can "
     "legitimately be new — hence warning outside CI).",
     profiles=(("ci", ERROR),))
rule("budget/stale-baseline", WARNING,
     "budgets.json pins groups the plan no longer produces",
     "a stale baseline entry means the plan's group structure changed "
     "(count or signature) without re-baselining — the remaining pins "
     "may be comparing unlike programs.",
     profiles=(("ci", ERROR),))
rule("budget/env-mismatch", WARNING,
     "budgets.json was recorded under a different environment",
     "cost envelopes depend on the smoke/full workload scale and the jax "
     "version that lowered them; comparing across environments would "
     "flag phantom drift, so budget checks are skipped (re-record with "
     "--update-budgets in this environment to re-arm them).")
rule("budget/unknown-dtype", WARNING,
     "HLO parser met a dtype with no known byte width",
     "collective-byte totals silently defaulting unknown dtypes to 4 "
     "bytes is exactly the wrong-total bug this rule surfaces; add the "
     "dtype to roofline.hlo._DTYPE_BYTES.",
     profiles=(("ci", ERROR),))


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic from any lint layer."""

    rule: str               # a RULES key
    where: str              # "src/...py:123" | "fig12/group0" | plan name
    message: str
    severity: Optional[Severity] = None   # None: the rule's default

    @property
    def effective_severity(self) -> Severity:
        return self.severity_under(None)

    def severity_under(self, profile: Optional[str]) -> Severity:
        """Effective severity under a profile; an explicit per-finding
        severity (a downgrade a layer chose deliberately) always wins."""
        if self.severity is not None:
            return self.severity
        return severity_for(self.rule, profile)


def make_finding(rule_id: str, where: str, message: str,
                 severity: Optional[Severity] = None) -> Finding:
    if rule_id not in RULES:
        raise KeyError(f"unknown rule {rule_id!r}")
    return Finding(rule=rule_id, where=where, message=message,
                   severity=severity)


@dataclasses.dataclass
class AnalysisReport:
    """Findings from every layer plus the positive proofs per analyzed plan.

    ``profile`` selects the severity profile every aggregate view
    (``errors``/``warnings``/``ok``/``render``/``to_json``) resolves
    through; None keeps each rule's declared severity (== "bench").
    """

    findings: list[Finding] = dataclasses.field(default_factory=list)
    # plan/fixture name -> established facts, e.g. {"groups_predicted": 2,
    # "groups_traced": 2, "kernel_groups_proven": 1, "f64_ops": 0}
    proofs: dict = dataclasses.field(default_factory=dict)
    profile: Optional[str] = None

    def extend(self, findings) -> None:
        self.findings.extend(findings)

    def severity_of(self, f: Finding) -> Severity:
        return f.severity_under(self.profile)

    def errors(self) -> list[Finding]:
        return [f for f in self.findings if self.severity_of(f) == ERROR]

    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if self.severity_of(f) == WARNING]

    def ok(self) -> bool:
        return not self.errors()

    def render(self, verbose: bool = False) -> str:
        lines = []
        shown = sorted(
            self.findings,
            key=lambda f: (_SEV_ORDER[self.severity_of(f)], f.rule, f.where))
        if not verbose:
            shown = [f for f in shown if self.severity_of(f) != INFO]
        for f in shown:
            lines.append(f"{self.severity_of(f).upper():7s} {f.rule:24s} "
                         f"{f.where}: {f.message}")
        for name in sorted(self.proofs):
            facts = self.proofs[name]
            body = ", ".join(f"{k}={v}" for k, v in facts.items())
            lines.append(f"PROOF   {name}: {body}")
        n_err, n_warn = len(self.errors()), len(self.warnings())
        n_info = len(self.findings) - n_err - n_warn
        prof = f" [profile={self.profile}]" if self.profile else ""
        lines.append(f"== {n_err} errors, {n_warn} warnings, {n_info} info; "
                     f"{'FAIL' if n_err else 'PASS'}{prof}")
        return "\n".join(lines)

    def to_json(self) -> dict:
        """JSON-serializable dump (the CI workflow-artifact surface)."""
        return {
            "profile": self.profile,
            "ok": self.ok(),
            "findings": [
                {"rule": f.rule, "where": f.where, "message": f.message,
                 "severity": self.severity_of(f)}
                for f in self.findings],
            "proofs": self.proofs,
            "counts": {"errors": len(self.errors()),
                       "warnings": len(self.warnings()),
                       "total": len(self.findings)},
        }
