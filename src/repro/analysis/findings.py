"""Finding records and the analysis report — the one output surface all
three lint layers (IR, plan, source) emit into.

A `Finding` is a structured diagnostic: a rule id (``layer/rule-name``), a
severity, a location string (``file:line`` for source findings, a
``plan/group`` label for IR and plan findings) and a human message.  The
`AnalysisReport` aggregates findings plus per-plan *proofs* — the positive
facts the verifier established (kernel present in N groups, groups
predicted == groups traced, zero f64 ops) — and renders both; ``--ci``
exits nonzero iff any error-severity finding survives.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["Severity", "Finding", "Rule", "AnalysisReport", "RULES",
           "rule", "make_finding"]

# Severity order (render sorts errors first).
ERROR = "error"
WARNING = "warning"
INFO = "info"
Severity = str
_SEV_ORDER = {ERROR: 0, WARNING: 1, INFO: 2}


@dataclasses.dataclass(frozen=True)
class Rule:
    """One catalog entry: what a rule proves and why it matters."""

    id: str                 # "ir/f64-promotion"
    severity: Severity      # default severity of its findings
    summary: str            # one line, shown in renders
    rationale: str          # why violating it invalidates results


# The full rule catalog.  DESIGN.md §7 documents each entry; tests assert
# every rule here fires on a deliberately-broken fixture.
RULES: dict[str, Rule] = {}


def rule(id: str, severity: Severity, summary: str, rationale: str) -> Rule:
    r = Rule(id=id, severity=severity, summary=summary, rationale=rationale)
    if id in RULES:
        raise ValueError(f"duplicate rule id {id!r}")
    RULES[id] = r
    return r


# --- IR layer -------------------------------------------------------------
rule("ir/kernel-missing", ERROR,
     "fused CC-tick kernel absent from a kernel-enabled lowering",
     "use_pallas_kernel=True must place the Pallas mltcp_cc_tick "
     "pallas_call inside the tick scan; its absence means the sweep runs "
     "the jnp oracle (perf claims about the fused path are void).")
rule("ir/kernel-fallback", ERROR,
     "config statically forces the kernel->oracle fallback",
     "non-default favoritism / non-linear F are outside the kernel's "
     "specialization; requesting use_pallas_kernel for such a config "
     "can only ever run unfused — fix the config or drop the flag.")
rule("ir/kernel-unexpected", WARNING,
     "pallas_call present in a kernel-disabled lowering",
     "a program that was asked for the jnp oracle must not dispatch the "
     "kernel; oracle-vs-kernel bit-equality checks depend on it.")
rule("ir/f64-promotion", ERROR,
     "float64 value or convert_element_type to f64 in the lowered program",
     "the engine and kernel are pinned bit-stable in f32; a silent f64 "
     "promotion (e.g. under jax_enable_x64) breaks kernel/oracle "
     "bit-equality and doubles memory traffic.")
rule("ir/host-callback", ERROR,
     "host callback / debug print / io callback in the hot path",
     "callbacks inside the tick scan force device->host syncs every "
     "iteration — timing figures measured with one in place are invalid.")
rule("ir/nested-control", ERROR,
     "non-whitelisted while/cond inside the tick-scan body",
     "the tick body is straight-line vectorized math; a stray lax.cond / "
     "while_loop usually means a python branch escaped tracing and will "
     "serialize the vmapped sweep.")

# --- plan layer -----------------------------------------------------------
rule("plan/group-split", INFO,
     "two plan points compile separately (group-split explainer)",
     "every extra compile group is an extra trace+compile; the explainer "
     "names the exact canonicalized fields that differ so splits are "
     "always accounted for.")
rule("plan/avoidable-split", WARNING,
     "compile-group split on value-only fields",
     "the differing fields are plain numeric values that could ride the "
     "batched sweep as traced SweepParams leaves (the PR-4 pattern); the "
     "split wastes traces.")
rule("plan/group-mismatch", ERROR,
     "predicted compile groups != programs actually traced",
     "grouping canonicalization and the jit static signature disagree — "
     "either the canonicalizer merges points the jit cache splits "
     "(silent retraces) or vice versa.")
rule("plan/retrace", ERROR,
     "re-tracing an already-traced compile group",
     "a warm group must hit the jaxpr cache; a retrace means something "
     "unhashable or dynamic leaked into the static config signature.")

# --- source layer ---------------------------------------------------------
rule("src/np-in-scan", ERROR,
     "numpy call in a function reachable from a scan body",
     "np.* inside traced code either fails under vmap/jit or silently "
     "constant-folds per trace; scan bodies must be pure jnp. "
     "Trace-time constants on static shapes may be whitelisted inline.")
rule("src/float-cast-traced", ERROR,
     "python float()/int()/bool() applied to a traced value",
     "concretizing a tracer raises under jit, or — worse — bakes a "
     "trace-time constant into the program so sweeps silently reuse the "
     "first point's value.")
rule("src/branch-on-traced", ERROR,
     "python `if` on a traced value inside traced code",
     "python control flow on tracers raises ConcretizationTypeError "
     "under jit; use jnp.where / lax.cond.")
rule("src/f64-literal", ERROR,
     "float64 literal outside NumPy-side config plumbing",
     "jnp.float64 / astype('float64') in traced code promotes the "
     "bit-stable f32 pipeline; np.float64 is fine only in numpy-side "
     "config plumbing (JobSpec.simple style) that never reaches a scan.")
rule("src/unit-suffix", ERROR,
     "add/subtract/compare across conflicting unit suffixes",
     "names suffixed _bytes/_s/_bps/_ticks carry units; summing or "
     "comparing across units (without a converting multiply/divide) is "
     "the classic silent protocol-parameter bug the RoCE CC sensitivity "
     "studies warn about.")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic from any lint layer."""

    rule: str               # a RULES key
    where: str              # "src/...py:123" | "fig12/group0" | plan name
    message: str
    severity: Optional[Severity] = None   # None: the rule's default

    @property
    def effective_severity(self) -> Severity:
        if self.severity is not None:
            return self.severity
        return RULES[self.rule].severity


def make_finding(rule_id: str, where: str, message: str,
                 severity: Optional[Severity] = None) -> Finding:
    if rule_id not in RULES:
        raise KeyError(f"unknown rule {rule_id!r}")
    return Finding(rule=rule_id, where=where, message=message,
                   severity=severity)


@dataclasses.dataclass
class AnalysisReport:
    """Findings from every layer plus the positive proofs per analyzed plan."""

    findings: list[Finding] = dataclasses.field(default_factory=list)
    # plan/fixture name -> established facts, e.g. {"groups_predicted": 2,
    # "groups_traced": 2, "kernel_groups_proven": 1, "f64_ops": 0}
    proofs: dict = dataclasses.field(default_factory=dict)

    def extend(self, findings) -> None:
        self.findings.extend(findings)

    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.effective_severity == ERROR]

    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.effective_severity == WARNING]

    def ok(self) -> bool:
        return not self.errors()

    def render(self, verbose: bool = False) -> str:
        lines = []
        shown = sorted(
            self.findings,
            key=lambda f: (_SEV_ORDER[f.effective_severity], f.rule, f.where))
        if not verbose:
            shown = [f for f in shown if f.effective_severity != INFO]
        for f in shown:
            lines.append(f"{f.effective_severity.upper():7s} {f.rule:24s} "
                         f"{f.where}: {f.message}")
        for name in sorted(self.proofs):
            facts = self.proofs[name]
            body = ", ".join(f"{k}={v}" for k, v in facts.items())
            lines.append(f"PROOF   {name}: {body}")
        n_err, n_warn = len(self.errors()), len(self.warnings())
        n_info = len(self.findings) - n_err - n_warn
        lines.append(f"== {n_err} errors, {n_warn} warnings, {n_info} info; "
                     f"{'FAIL' if n_err else 'PASS'}")
        return "\n".join(lines)
