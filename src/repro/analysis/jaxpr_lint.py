"""IR lint: walk the *traced* sweep program and prove lowering invariants
without executing anything.

`engine.trace_sweep` gives us the ``jax.stages.Traced`` for a (cfg, sweep)
pair — same jaxpr cache as ``.lower()``/execution, so the program we lint
is byte-for-byte the program a later ``simulate_sweep`` runs.  The walk
recurses through every sub-jaxpr (scan bodies, pjit calls, cond branches,
the pallas_call kernel body) and checks, per compile group:

* the fused ``mltcp_cc_tick`` ``pallas_call`` is present exactly when the
  config statically entitles it (``kernel_expectation``) — the static
  proof that the PR-3 silent-fallback bug stays dead;
* no value or ``convert_element_type`` lands in float64 anywhere in the
  program (bit-stable f32 pipeline);
* no host callbacks / debug prints in the hot path;
* no non-whitelisted ``while``/``cond`` inside the tick-scan body.

We lint at the jaxpr level rather than StableHLO on purpose: under
``REPRO_INTERPRET=1`` the Pallas custom call never reaches HLO (interpret
mode lowers to plain HLO ops), but the ``pallas_call`` primitive is always
visible in the jaxpr, so the same proof holds on CPU CI and on device.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import jax

from repro.analysis.findings import Finding, make_finding

__all__ = ["kernel_expectation", "lint_closed_jaxpr", "lint_sweep",
           "HOST_CALLBACK_PRIMITIVES"]

# Primitives that round-trip through the host.  Any of these inside the
# sweep program stalls the device once per tick.
HOST_CALLBACK_PRIMITIVES = frozenset({
    "debug_callback", "pure_callback", "io_callback", "outside_call",
    "infeed", "outfeed", "debug_print", "host_local_array_to_global_array",
})

# Control-flow primitives that must not appear inside the tick-scan body
# unless whitelisted by name.
_NESTED_CONTROL = frozenset({"while", "cond"})

# Equation params whose values carry sub-jaxprs we must recurse into.
_F64 = "float64"


def kernel_expectation(cfg, sweep) -> str:
    """What the lowering *must* contain: "fused" | "fallback" | "off".

    Mirrors the static fallback decision in ``kernels.ops.mltcp_cc_tick``
    (and nothing else — that's the point: if ops.py and this function ever
    disagree, the kernel-missing / kernel-unexpected rules catch it on the
    next lint run).
    """
    if not cfg.use_pallas_kernel:
        return "off"
    if sweep.static_job_factors is not None:
        # Static-baseline factors ride in as operands; favoritism/F moot.
        return "fused"
    proto = cfg.protocol
    if proto.favoritism != "largest_data_sent" or proto.f_spec != "linear":
        return "fallback"
    return "fused"


@dataclasses.dataclass
class _WalkState:
    pallas_calls: int = 0
    f64_ops: int = 0
    eqns: int = 0
    findings: list = dataclasses.field(default_factory=list)


def _sub_jaxprs(params) -> Iterable:
    """Yield every (Closed)Jaxpr reachable from an eqn's params."""
    for val in params.values():
        stack = [val]
        while stack:
            v = stack.pop()
            if isinstance(v, (jax.core.ClosedJaxpr, jax.core.Jaxpr)):
                yield v
            elif isinstance(v, (list, tuple)):
                stack.extend(v)


def _aval_dtype(v) -> Optional[str]:
    aval = getattr(v, "aval", None)
    dtype = getattr(aval, "dtype", None)
    return None if dtype is None else str(dtype)


def _walk(jaxpr, state: _WalkState, label: str, whitelist: frozenset,
          in_scan: bool, in_kernel: bool) -> None:
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        for c in jaxpr.consts:
            if str(getattr(c, "dtype", "")) == _F64:
                state.f64_ops += 1
                state.findings.append(make_finding(
                    "ir/f64-promotion", label,
                    f"float64 constant {getattr(c, 'shape', ())} captured "
                    f"by the program"))
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        state.eqns += 1
        name = eqn.primitive.name

        for v in eqn.outvars:
            if _aval_dtype(v) == _F64:
                state.f64_ops += 1
                state.findings.append(make_finding(
                    "ir/f64-promotion", label,
                    f"`{name}` produces a float64 value "
                    f"{getattr(v.aval, 'shape', ())}"
                    + (" inside the tick scan" if in_scan else "")))
                break   # one finding per eqn is enough
        if (name == "convert_element_type"
                and str(eqn.params.get("new_dtype", "")) == _F64):
            # outvar check above already fired; this branch only matters
            # for exotic converts whose outvar aval lies (shouldn't
            # happen, kept as a belt-and-braces count)
            pass

        if name in HOST_CALLBACK_PRIMITIVES:
            state.findings.append(make_finding(
                "ir/host-callback", label,
                f"host callback primitive `{name}`"
                + (" inside the tick scan" if in_scan else "")))

        if (in_scan and not in_kernel and name in _NESTED_CONTROL
                and name not in whitelist):
            state.findings.append(make_finding(
                "ir/nested-control", label,
                f"`{name}` inside the tick-scan body (whitelist via "
                f"the lint whitelist= option if intentional)"))

        if name == "pallas_call":
            state.pallas_calls += 1

        sub_in_scan = in_scan or name == "scan"
        sub_in_kernel = in_kernel or name == "pallas_call"
        for sub in _sub_jaxprs(eqn.params):
            _walk(sub, state, label, whitelist, sub_in_scan, sub_in_kernel)


def lint_closed_jaxpr(closed_jaxpr, *, label: str = "<jaxpr>",
                      expectation: str = "off",
                      whitelist: frozenset = frozenset(),
                      ) -> tuple[list[Finding], dict]:
    """Lint one ClosedJaxpr against `expectation` ("fused"/"fallback"/"off").

    Returns (findings, facts); facts = {"pallas_calls", "f64_ops", "eqns"}.
    """
    state = _WalkState()
    _walk(closed_jaxpr, state, label, frozenset(whitelist),
          in_scan=False, in_kernel=False)

    if expectation == "fused" and state.pallas_calls == 0:
        state.findings.append(make_finding(
            "ir/kernel-missing", label,
            "use_pallas_kernel config lowered with no pallas_call in the "
            "program — the CC tick is running the jnp oracle"))
    elif expectation in ("off", "fallback") and state.pallas_calls > 0:
        state.findings.append(make_finding(
            "ir/kernel-unexpected", label,
            f"{state.pallas_calls} pallas_call(s) in a lowering that "
            f"expected the jnp oracle (expectation={expectation})"))
    if expectation == "fallback":
        state.findings.append(make_finding(
            "ir/kernel-fallback", label,
            "config requests use_pallas_kernel but statically forces the "
            "jnp-oracle fallback (non-default favoritism or non-linear F "
            "without static factors); drop the flag or fix the config"))

    facts = {"pallas_calls": state.pallas_calls, "f64_ops": state.f64_ops,
             "eqns": state.eqns}
    return state.findings, facts


def lint_sweep(cfg, sweep, *, label: str,
               whitelist: frozenset = frozenset(),
               ) -> tuple[list[Finding], dict]:
    """Trace (never execute) the sweep program for (cfg, sweep) and lint it.

    Tracing shares the jit cache with execution, so calling this before a
    run costs one trace total, and calling it after a run costs zero.
    """
    from repro.netsim import engine

    traced = engine.trace_sweep(cfg, sweep)
    expectation = kernel_expectation(cfg, sweep)
    findings, facts = lint_closed_jaxpr(
        traced.jaxpr, label=label, expectation=expectation,
        whitelist=whitelist)
    facts["expectation"] = expectation
    return findings, facts
