"""HLO cost budgets (layer 5): pin every compile group's flop / byte /
memory / collective envelope against a schema-versioned baseline.

For each compile group `plan_lint` predicts, the group program is lowered
through the shared jit cache (`engine.lower_sweep` reuses the trace the IR
and kernel lints already paid for) and compiled once per session, then
XLA's `cost_analysis()` / `memory_analysis()` plus the roofline HLO-text
parser are folded into one envelope (`roofline.hlo.cost_envelope`).  The
envelope is compared leaf-by-leaf against `budgets.json` (committed next
to this module): a metric that drifts beyond its per-metric relative
tolerance raises ``budget/drift`` naming the plan, group signature and
metric, so a silent flop or HBM regression fails CI with an actionable
diff instead of a vague "slower".

Baseline discipline:

* the file records an *environment fingerprint* (REPRO_SMOKE/REPRO_FULL,
  jax version, kernel interpret mode).  jax is intentionally unpinned
  (pyproject: ``jax>=0.4.30``), and smoke mode changes n_ticks/K, so a
  mismatched environment downgrades every compare to one
  ``budget/env-mismatch`` warning rather than flagging phantom drift;
* groups present in the run but absent from the baseline raise
  ``budget/missing-baseline``; baseline groups no longer produced raise
  ``budget/stale-baseline`` — both WARNING by default, promoted to ERROR
  under the ci profile so the file can't rot;
* intentional cost changes re-record via
  ``python -m repro.analysis --ci --update-budgets`` (writes the file,
  never fails on drift).

Group identity is `experiment._group_signature` — the same string the
plan lint and benchmark health checks key on — qualified by the plan
label, so padded-group merges keep a stable identity across runs.
"""
from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path
from typing import Optional

from repro.analysis.findings import Finding, make_finding

__all__ = ["SCHEMA", "DEFAULT_PATH", "METRICS", "DEFAULT_TOLERANCES",
           "env_fingerprint", "measure_group", "check_envelope",
           "BudgetBook"]

SCHEMA = 1

# Committed next to the module so `python -m repro.analysis --ci` finds it
# from any cwd and the baseline travels with the code it describes.
DEFAULT_PATH = Path(__file__).with_name("budgets.json")

# The envelope leaves that get budget-checked, with per-metric relative
# tolerance: |new - base| <= tol * max(|base|, 1).
#   * flops/transcendentals are deterministic per program — tight;
#   * bytes_accessed includes XLA's fusion-dependent traffic model —
#     loose enough to absorb minor scheduling changes;
#   * argument/output bytes are exact interface contracts — zero;
#   * temp bytes swing with buffer assignment — loosest;
#   * collective bytes are an interface contract of the partitioner — zero.
DEFAULT_TOLERANCES = {
    "flops": 0.02,
    "transcendentals": 0.02,
    "bytes_accessed": 0.10,
    "argument_bytes": 0.0,
    "output_bytes": 0.0,
    "temp_bytes": 0.50,
    "peak_bytes": 0.25,
    "collective_bytes": 0.0,
}
METRICS = tuple(DEFAULT_TOLERANCES)


def env_fingerprint() -> dict:
    """What the recorded numbers depend on besides the code itself."""
    import jax

    from repro.kernels import ops

    return {
        "jax": jax.__version__,
        "repro_smoke": os.environ.get("REPRO_SMOKE", ""),
        "repro_full": os.environ.get("REPRO_FULL", ""),
        "kernel_interpret": bool(ops.INTERPRET),
    }


def measure_group(cfg, sweep) -> dict:
    """Compile one group (via the shared jit/lowering cache) and return its
    cost envelope.  The `.compile()` is a real XLA run (~1 s/group on CPU);
    layer 5 is the only analysis layer that pays it."""
    from repro.netsim import engine
    from repro.roofline import hlo

    compiled = engine.lower_sweep(cfg, sweep).compile()
    return hlo.cost_envelope(compiled)


def check_envelope(base: dict, new: dict, tolerances: dict,
                   *, where: str) -> list[Finding]:
    """Leaf-level drift compare of one group's envelope vs its baseline."""
    findings = []
    for metric in METRICS:
        if metric not in base:
            continue                       # older baseline, fewer leaves
        tol = tolerances.get(metric, 0.0)
        b, n = float(base[metric]), float(new.get(metric, 0.0))
        if abs(n - b) > tol * max(abs(b), 1.0):
            pct = (n - b) / b * 100.0 if b else float("inf")
            findings.append(make_finding(
                "budget/drift", where,
                f"{metric}: measured {n:.6g} vs baseline {b:.6g} "
                f"({pct:+.1f}%, tolerance ±{tol * 100:.0f}%) — "
                f"re-record with --update-budgets if intentional"))
    return findings


@dataclasses.dataclass
class BudgetBook:
    """One analysis run's budget ledger: observe measured envelopes, then
    `finish()` into findings (check mode) or `save()` a new baseline
    (update mode)."""

    path: Path = DEFAULT_PATH
    update: bool = False
    tolerances: dict = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_TOLERANCES))

    def __post_init__(self):
        self.path = Path(self.path)
        self._measured: dict[str, dict[str, dict]] = {}   # plan -> sig -> env
        self._baseline: Optional[dict] = None
        self._load_error: Optional[str] = None
        if self.path.exists():
            try:
                data = json.loads(self.path.read_text())
                if data.get("schema") != SCHEMA:
                    self._load_error = (f"schema {data.get('schema')!r} "
                                        f"!= supported {SCHEMA}")
                else:
                    self._baseline = data
                    self.tolerances = dict(DEFAULT_TOLERANCES,
                                           **data.get("tolerances", {}))
            except (OSError, json.JSONDecodeError) as e:
                self._load_error = str(e)

    # -- recording --------------------------------------------------------

    def observe(self, plan: str, signature: str, envelope: dict) -> None:
        env = {m: envelope.get(m, 0.0) for m in METRICS}
        env["unknown_dtypes"] = list(envelope.get("unknown_dtypes", ()))
        self._measured.setdefault(plan, {})[signature] = env

    # -- check mode -------------------------------------------------------

    @property
    def env_matches(self) -> bool:
        if self._baseline is None:
            return False
        return self._baseline.get("env") == env_fingerprint()

    def finish(self) -> list[Finding]:
        """All budget findings for the observed run (check mode)."""
        findings: list[Finding] = []
        for plan, groups in self._measured.items():
            for sig, env in groups.items():
                for d in env.get("unknown_dtypes", ()):
                    findings.append(make_finding(
                        "budget/unknown-dtype", f"{plan} :: {sig}",
                        f"HLO collective result uses dtype {d!r} missing "
                        f"from roofline._DTYPE_BYTES (assumed 4 B/elem)"))
        if self._baseline is None:
            why = (f"cannot read {self.path} ({self._load_error})"
                   if self._load_error else f"{self.path} does not exist")
            findings.append(make_finding(
                "budget/missing-baseline", "budgets",
                f"no cost baseline: {why} — record one with "
                f"--update-budgets"))
            return findings
        if not self.env_matches:
            findings.append(make_finding(
                "budget/env-mismatch", "budgets",
                f"baseline recorded under {self._baseline.get('env')} but "
                f"running under {env_fingerprint()} — drift compares "
                f"skipped (re-record under the CI env to re-arm)"))
            return findings
        base_plans = self._baseline.get("plans", {})
        for plan, groups in self._measured.items():
            base_groups = {g["signature"]: g
                           for g in base_plans.get(plan, {}).get("groups", [])}
            for sig, env in groups.items():
                where = f"{plan} :: {sig}"
                if sig not in base_groups:
                    findings.append(make_finding(
                        "budget/missing-baseline", where,
                        "compile group has no recorded baseline — record "
                        "with --update-budgets"))
                    continue
                findings.extend(check_envelope(
                    base_groups[sig], env, self.tolerances, where=where))
            for sig in base_groups:
                if sig not in groups:
                    findings.append(make_finding(
                        "budget/stale-baseline", f"{plan} :: {sig}",
                        "baseline group no longer produced by this plan — "
                        "prune with --update-budgets"))
        return findings

    # -- update mode ------------------------------------------------------

    def save(self) -> Path:
        """Write the observed envelopes as the new baseline."""
        plans = {
            plan: {"groups": [
                dict(signature=sig,
                     **{m: env[m] for m in METRICS})
                for sig, env in groups.items()]}
            for plan, groups in sorted(self._measured.items())
        }
        data = {
            "schema": SCHEMA,
            "env": env_fingerprint(),
            "tolerances": self.tolerances,
            "plans": plans,
        }
        tmp = self.path.with_suffix(".json.tmp")
        tmp.write_text(json.dumps(data, indent=1, sort_keys=True) + "\n")
        os.replace(tmp, self.path)
        return self.path

    # -- benchmark cross-check -------------------------------------------

    def baseline_for(self, plan: str, signature: str) -> Optional[dict]:
        """The recorded envelope of one group, or None (no baseline / env
        mismatch / unknown group)."""
        if self._baseline is None or not self.env_matches:
            return None
        for g in self._baseline.get("plans", {}).get(plan, {}) \
                               .get("groups", []):
            if g["signature"] == signature:
                return g
        return None

    def matches_any(self, signature: str, envelope: dict) -> Optional[bool]:
        """Cross-check a *measured* group profile against the prediction:
        does this envelope match (within tolerance) any recorded group
        with the same structural signature?  Benchmark plan labels differ
        from the analysis registry's, so candidates come from every
        recorded plan, keyed on the `_group_signature` tail of the stored
        ``"group<i>|<signature>"`` id.  Returns None when no baseline, the
        env mismatches, or no candidate shares the signature —
        `benchmarks.common` counts only a definite False as a mismatch."""
        if self._baseline is None or not self.env_matches:
            return None
        candidates = [
            g for plan in self._baseline.get("plans", {}).values()
            for g in plan.get("groups", [])
            if g["signature"].split("|", 1)[-1] == signature]
        if not candidates:
            return None
        return any(not check_envelope(g, envelope, self.tolerances,
                                      where="") for g in candidates)
