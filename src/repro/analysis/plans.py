"""Named lintable plans: the figure suites' grids, resolvable without
running them.

Each entry lazily imports its benchmark suite and calls its
``make_plan()`` factory — benchmarks live outside ``src`` (repo-root
``benchmarks/``), so the registry only works from a repo checkout; the
error message says so instead of a bare ImportError.  ``CI_PLANS`` is the
set the ``--ci`` gate proves on every push.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

__all__ = ["PlanEntry", "PLANS", "CI_PLANS", "resolve_entry"]


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    name: str
    factory: Callable                        # () -> netsim.Plan
    telemetry: Optional[Callable] = None     # () -> TelemetrySpec, if armed
    lint_unarmed: bool = False               # also lint the unarmed lowering


def _fig12():
    from benchmarks import stragglers
    return stragglers.make_plan()


def _fig13():
    from benchmarks import partial_compat
    return partial_compat.make_plan()


def _fig5():
    from benchmarks import timeline
    return timeline.make_plan()


def _fig5_telemetry():
    from benchmarks import timeline
    return timeline.telemetry_spec()


def _kernel_sweep():
    from benchmarks import kernel_sweep
    return kernel_sweep.make_plan()


def _churn():
    from benchmarks import churn
    return churn.make_plan()


PLANS: dict[str, PlanEntry] = {
    "fig12": PlanEntry("fig12", _fig12),
    "fig13": PlanEntry("fig13", _fig13),
    # fig5 runs armed (probe ring buffers in the scan state); lint both the
    # armed and unarmed programs — telemetry must not perturb either proof
    "fig5": PlanEntry("fig5", _fig5, telemetry=_fig5_telemetry,
                      lint_unarmed=True),
    "kernel_sweep": PlanEntry("kernel_sweep", _kernel_sweep),
    # the fault-injection suite: fused kernel + armed faults + reinterleave
    # detector — the gate proves faults never unfuse the CC-tick kernel.
    # make_plan stamps telemetry+faults on its configs itself (the spec
    # depends on per-point fault structure), so no telemetry factory here.
    "churn": PlanEntry("churn", _churn),
}

CI_PLANS = ("fig12", "fig13", "fig5", "kernel_sweep", "churn")


def resolve_entry(name: str):
    """-> (plan, telemetry_spec_or_None, lint_unarmed) for a registry name."""
    if name not in PLANS:
        raise KeyError(
            f"unknown plan {name!r}; known: {', '.join(sorted(PLANS))}")
    entry = PLANS[name]
    try:
        plan = entry.factory()
    except ImportError as e:
        raise ImportError(
            f"plan {name!r} needs the repo-root `benchmarks/` package on "
            f"sys.path (run from a repo checkout): {e}") from e
    telemetry = entry.telemetry() if entry.telemetry is not None else None
    return plan, telemetry, entry.lint_unarmed
