"""CLI: ``python -m repro.analysis [--ci] [--plan <suite>] [...]``.

* no flags — source lint only (fast; no benchmark imports);
* ``--plan fig12`` (repeatable) — also statically verify that suite's
  lowerings (registry names: see `repro.analysis.plans.PLANS`);
* ``--ci`` — the gate: defaults the plan set to `CI_PLANS`, treats the
  process as cold (strict groups-predicted == groups-traced proof), arms
  the HLO cost budgets (layer 5) and exits 1 on any error-severity
  finding under the active profile;
* ``--profile ci|bench|notebook`` — severity profile (ci promotes
  baseline-hygiene warnings to errors; notebook demotes errors to
  advisory warnings); defaults to ``ci`` under ``--ci``, else ``bench``;
* ``--update-budgets`` — re-record `analysis/budgets.json` from this
  run's measured envelopes instead of checking against it (the documented
  path for intentional cost changes — commit the rewritten file);
* ``--report-json PATH`` — also dump the machine-readable
  `AnalysisReport` (CI uploads it as a workflow artifact);
* ``--list-rules`` — print the full rule catalog (id, layer, default
  severity, per-profile severities) and exit.
"""
from __future__ import annotations

import argparse
import sys


def _list_rules() -> str:
    from repro.analysis.findings import PROFILES, RULES

    rows = [("rule", "layer", "default", *PROFILES)]
    for rid in sorted(RULES):
        r = RULES[rid]
        rows.append((rid, r.layer, r.severity,
                     *(r.severity_in(p) for p in PROFILES)))
    widths = [max(len(row[i]) for row in rows) for i in range(len(rows[0]))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip()
             for row in rows]
    lines.insert(1, "  ".join("-" * w for w in widths))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static verifier: plan, IR, source, kernel-body and "
                    "HLO-budget lints")
    ap.add_argument("--ci", action="store_true",
                    help="gate mode: default plan set, strict cold-trace "
                         "proof, budget enforcement, exit 1 on errors")
    ap.add_argument("--plan", action="append", default=[],
                    metavar="SUITE", help="lint a named plan (repeatable)")
    ap.add_argument("--profile", choices=("ci", "bench", "notebook"),
                    default=None,
                    help="severity profile (default: ci under --ci, "
                         "else bench)")
    ap.add_argument("--no-source", action="store_true",
                    help="skip the source lint layer")
    ap.add_argument("--no-budgets", action="store_true",
                    help="skip the HLO budget layer (no per-group compile)")
    ap.add_argument("--update-budgets", action="store_true",
                    help="re-record analysis/budgets.json from this run "
                         "instead of checking against it")
    ap.add_argument("--budgets-path", default=None, metavar="PATH",
                    help="override the budgets.json location")
    ap.add_argument("--report-json", default=None, metavar="PATH",
                    help="also write the report as JSON")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also print info-severity findings")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(_list_rules())
        return 0

    from repro.analysis import CI_PLANS, run_analysis
    from repro.analysis.hlo_budget import DEFAULT_PATH, BudgetBook

    plan_names = list(args.plan)
    if args.ci and not plan_names:
        plan_names = list(CI_PLANS)
    profile = args.profile or ("ci" if args.ci else "bench")

    budgets = None
    want_budgets = (args.ci or args.update_budgets) and not args.no_budgets
    if want_budgets and plan_names:
        budgets = BudgetBook(path=args.budgets_path or DEFAULT_PATH,
                             update=args.update_budgets)

    report = run_analysis(plan_names, source=not args.no_source,
                          expect_cold=args.ci, profile=profile,
                          budgets=budgets)
    print(report.render(verbose=args.verbose))
    if budgets is not None and args.update_budgets:
        print(f"budgets recorded -> {budgets.save()}")
    if args.report_json:
        import json
        from pathlib import Path

        Path(args.report_json).write_text(
            json.dumps(report.to_json(), indent=1) + "\n")
    return 1 if (args.ci and not report.ok()) else 0


if __name__ == "__main__":
    sys.exit(main())
