"""CLI: ``python -m repro.analysis [--ci] [--plan <suite>] [...]``.

* no flags — source lint only (fast; no benchmark imports);
* ``--plan fig12`` (repeatable) — also statically verify that suite's
  lowerings (registry names: see `repro.analysis.plans.PLANS`);
* ``--ci`` — the gate: defaults the plan set to `CI_PLANS`, treats the
  process as cold (strict groups-predicted == groups-traced proof) and
  exits 1 on any error-severity finding.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static verifier: IR lint, plan lint, source lint")
    ap.add_argument("--ci", action="store_true",
                    help="gate mode: default plan set, strict cold-trace "
                         "proof, exit 1 on errors")
    ap.add_argument("--plan", action="append", default=[],
                    metavar="SUITE", help="lint a named plan (repeatable)")
    ap.add_argument("--no-source", action="store_true",
                    help="skip the source lint layer")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also print info-severity findings")
    args = ap.parse_args(argv)

    from repro.analysis import CI_PLANS, run_analysis

    plan_names = list(args.plan)
    if args.ci and not plan_names:
        plan_names = list(CI_PLANS)

    report = run_analysis(plan_names, source=not args.no_source,
                          expect_cold=args.ci)
    print(report.render(verbose=args.verbose))
    return 1 if (args.ci and not report.ok()) else 0


if __name__ == "__main__":
    sys.exit(main())
