"""Kernel lint (layer 4): prove the Pallas CC-tick kernel *body*'s
invariants, per (algo, variant, factors) specialization.

The IR lint (layer 1) proves the ``pallas_call`` is present exactly when
the config entitles it; this layer walks *into* that equation — its
``grid_mapping`` (operand memory spaces, block shapes, grid) and its body
jaxpr — and checks the claims the perf story rests on:

* the `DynamicParams` operand is an f32[NDYN] **SMEM** ref and the body
  never writes it (``kernel/dyn-not-smem`` / ``kernel/dyn-written``);
* every flow-state operand is a default/VMEM ref with the
  ``(min(SUBLANES, rows), LANES)`` block tile ops.py packs
  (``kernel/state-not-vmem`` / ``kernel/block-misaligned``);
* the grid covers exactly ``rows`` with no remainder step
  (``kernel/grid-remainder``), and operand/result counts match the
  specialization (``kernel/operand-mismatch``);
* the body is straight-line elementwise f32: no f64 values, no
  gather/scatter, no while/cond/scan (``kernel/f64-in-body``,
  ``kernel/gather-scatter``, ``kernel/nested-control``);
* a static VMEM-bytes estimate per grid step (all in/out blocks, x2 for
  double buffering) stays under a configurable ceiling
  (``kernel/vmem-budget``).

The expectation comes from `kernels.ops.kernel_layout` — the same padding
math the dispatch uses — and the kernel equation is located in the
*already-traced* sweep jaxpr (`engine.trace_sweep` shares the jit cache),
so the whole layer costs zero extra traces.  Under a vmapped sweep the
pallas batching rule prepends batch dims: the grid gains leading axes,
block shapes gain ``Mapped`` sentinels, and the kernel name becomes
``_kernel_batched`` — `_normalize` strips all three so one expectation
covers K=1 and K>1 programs.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import jax

from repro.analysis.findings import Finding, make_finding
from repro.kernels import mltcp_step as ms

__all__ = ["find_kernel_eqns", "lint_kernel_eqn", "lint_kernel",
           "DEFAULT_VMEM_CEILING_BYTES"]

# Per-grid-step VMEM ceiling for the static estimate.  The real kernel's
# working set is ~44 blocks x 8x128 x 4 B ~= 180 KiB; 4 MiB leaves room
# for growth while still catching a runaway block shape long before the
# ~16 MiB physical VMEM (and its double-buffering halves) would.
DEFAULT_VMEM_CEILING_BYTES = 4 * 1024 * 1024

# Primitives that break the elementwise one-pass property.
_GATHER_SCATTER = frozenset({
    "gather", "scatter", "scatter-add", "scatter_add", "scatter_mul",
    "scatter_min", "scatter_max", "dynamic_gather",
})
_CONTROL = frozenset({"while", "cond", "scan"})
_F64 = "float64"


def _sub_jaxprs(params) -> Iterable:
    for val in params.values():
        stack = [val]
        while stack:
            v = stack.pop()
            if isinstance(v, (jax.core.ClosedJaxpr, jax.core.Jaxpr)):
                yield v
            elif isinstance(v, (list, tuple)):
                stack.extend(v)


def find_kernel_eqns(jaxpr) -> list:
    """Every CC-tick ``pallas_call`` eqn reachable from a (Closed)Jaxpr,
    matched by kernel-body name prefix (`ms.KERNEL_NAME`; the batching
    rule suffixes "_batched")."""
    out = []
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            name = str(getattr(eqn.params.get("name_and_src_info"),
                               "name", ""))
            if name.startswith(ms.KERNEL_NAME):
                out.append(eqn)
        for sub in _sub_jaxprs(eqn.params):
            out.extend(find_kernel_eqns(sub))
    return out


def _int_dims(block_shape) -> tuple:
    """Block dims with batching sentinels (`Mapped`, None) stripped — the
    per-grid-step tile shape."""
    return tuple(d for d in block_shape if isinstance(d, int))


def _space(block_mapping) -> str:
    """"smem" | "default" (ANY/VMEM) | other, from the transformed aval."""
    space = getattr(block_mapping.transformed_block_aval,
                    "memory_space", None)
    return "default" if space is None else str(space)


@dataclasses.dataclass
class _Normalized:
    grid: tuple                  # trailing (non-batch) grid dims
    n_batch_dims: int
    in_mappings: list            # BlockMapping per input operand
    out_mappings: list
    body: object                 # the body Jaxpr


def _normalize(eqn, expected: ms.KernelLayout) -> _Normalized:
    gm = eqn.params["grid_mapping"]
    n_batch = max(len(gm.grid) - len(expected.grid), 0)
    bms = list(gm.block_mappings)
    return _Normalized(
        grid=tuple(gm.grid[n_batch:]), n_batch_dims=n_batch,
        in_mappings=bms[:gm.num_inputs],
        out_mappings=bms[gm.num_inputs:gm.num_inputs + gm.num_outputs],
        body=eqn.params["jaxpr"])


def _walk_body(jaxpr, watched: frozenset, state: dict, label: str,
               findings: list) -> None:
    """Recurse the kernel body (and its pjit sub-jaxprs, threading which
    sub-invars alias the watched dyn ref) for f64 / gather-scatter /
    control-flow / dyn-write violations."""
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        for v in eqn.outvars:
            dtype = getattr(getattr(v, "aval", None), "dtype", None)
            if dtype is not None and str(dtype) == _F64:
                if not state["f64"]:
                    findings.append(make_finding(
                        "kernel/f64-in-body", label,
                        f"`{name}` produces a float64 value "
                        f"{getattr(v.aval, 'shape', ())} inside the "
                        f"kernel body"))
                state["f64"] += 1
                break
        if name in _GATHER_SCATTER:
            findings.append(make_finding(
                "kernel/gather-scatter", label,
                f"`{name}` inside the kernel body — every op must be "
                f"elementwise over the block tile"))
        if name in _CONTROL:
            findings.append(make_finding(
                "kernel/nested-control", label,
                f"`{name}` inside the kernel body — the specialization "
                f"is static, the body must be straight-line"))
        if (name == "swap" and eqn.invars
                and isinstance(eqn.invars[0], jax.core.Var)
                and eqn.invars[0] in watched):
            findings.append(make_finding(
                "kernel/dyn-written", label,
                "kernel body writes to the DynamicParams SMEM operand "
                "(read-only by contract)"))
        if name == "pjit":
            sub = eqn.params.get("jaxpr")
            if isinstance(sub, jax.core.ClosedJaxpr):
                # positional call: thread the watched-ref aliasing through
                sub_watched = frozenset(
                    sv for ov, sv in zip(eqn.invars, sub.jaxpr.invars)
                    if isinstance(ov, jax.core.Var) and ov in watched)
                _walk_body(sub, sub_watched, state, label, findings)
        else:
            for sub in _sub_jaxprs(eqn.params):
                _walk_body(sub, frozenset(), state, label, findings)


def lint_kernel_eqn(eqn, expected: ms.KernelLayout, *, label: str,
                    vmem_ceiling_bytes: int = DEFAULT_VMEM_CEILING_BYTES,
                    ) -> tuple[list[Finding], dict]:
    """Check one CC-tick pallas_call eqn against a specialization layout.

    Returns (findings, facts); facts = {"vmem_bytes_per_step",
    "body_eqns", "n_batch_dims"}.
    """
    findings: list[Finding] = []
    n = _normalize(eqn, expected)

    # --- operand/result counts mirror the specialization ----------------
    if (len(n.in_mappings) != expected.n_inputs
            or len(n.out_mappings) != expected.n_outputs):
        findings.append(make_finding(
            "kernel/operand-mismatch", label,
            f"{len(n.in_mappings)} inputs / {len(n.out_mappings)} outputs "
            f"!= specialization's {expected.n_inputs}/{expected.n_outputs} "
            f"(static_factors={expected.use_static_factors})"))

    # --- the dyn SMEM operand -------------------------------------------
    dyn_var = None
    if expected.dyn_index < len(n.in_mappings):
        dyn_bm = n.in_mappings[expected.dyn_index]
        dyn_shape = _int_dims(dyn_bm.block_shape)
        if _space(dyn_bm) != "smem" or dyn_shape != expected.dyn_shape:
            findings.append(make_finding(
                "kernel/dyn-not-smem", label,
                f"DynamicParams operand is {_space(dyn_bm)}{dyn_shape}, "
                f"expected smem{expected.dyn_shape}"))
        else:
            body = (n.body.jaxpr if isinstance(n.body, jax.core.ClosedJaxpr)
                    else n.body)
            dyn_var = body.invars[expected.dyn_index]

    # --- flow-state refs: VMEM, aligned tiles ---------------------------
    vmem_bytes = 0
    state_mappings = (list(enumerate(n.in_mappings)) +
                      list(enumerate(n.out_mappings)))
    for i, bm in state_mappings:
        if bm in n.in_mappings and i == expected.dyn_index:
            continue                       # the SMEM scalars, checked above
        dims = _int_dims(bm.block_shape)
        aval = bm.transformed_block_aval
        itemsize = getattr(getattr(aval, "dtype", None), "itemsize", 4)
        size = itemsize
        for d in dims:
            size *= d
        vmem_bytes += size
        kind = "in" if bm in n.in_mappings else "out"
        if _space(bm) not in ("default", "vmem", "ANY"):
            findings.append(make_finding(
                "kernel/state-not-vmem", label,
                f"state operand {kind}[{i}] lives in {_space(bm)} "
                f"(flow state must be VMEM-resident)"))
        if dims != expected.block:
            findings.append(make_finding(
                "kernel/block-misaligned", label,
                f"state operand {kind}[{i}] block {dims} != the "
                f"{expected.block} (SUBLANES, LANES) tile"))

    # --- grid covers rows exactly ---------------------------------------
    if n.grid != expected.grid or expected.rows % expected.block[0] != 0:
        findings.append(make_finding(
            "kernel/grid-remainder", label,
            f"grid {n.grid} (after {n.n_batch_dims} batch dim(s)) does "
            f"not cover rows={expected.rows} in {expected.block[0]}-row "
            f"blocks exactly (expected grid {expected.grid})"))

    # --- VMEM ceiling (x2: pipelined double buffering) ------------------
    est = 2 * vmem_bytes
    if est > vmem_ceiling_bytes:
        findings.append(make_finding(
            "kernel/vmem-budget", label,
            f"static VMEM estimate {est} B per grid step (2x {vmem_bytes} "
            f"B of blocks) exceeds the {vmem_ceiling_bytes} B ceiling"))

    # --- body: straight-line elementwise f32, dyn read-only -------------
    state = {"f64": 0}
    watched = frozenset() if dyn_var is None else frozenset({dyn_var})
    body_eqns = (n.body.jaxpr.eqns if isinstance(n.body, jax.core.ClosedJaxpr)
                 else n.body.eqns)
    _walk_body(n.body, watched, state, label, findings)

    facts = {"vmem_bytes_per_step": est, "body_eqns": len(body_eqns),
             "n_batch_dims": n.n_batch_dims}
    return findings, facts


def lint_kernel(cfg, sweep, *, label: str,
                vmem_ceiling_bytes: int = DEFAULT_VMEM_CEILING_BYTES,
                ) -> tuple[list[Finding], dict]:
    """Lint the CC-tick kernel body of one compile group's traced program.

    A no-op (empty findings, ``kernel_checked=False``) when the
    specialization does not expect the fused kernel — mirrored from
    `jaxpr_lint.kernel_expectation`, i.e. from ops.py's own dispatch —
    or when the kernel eqn is absent (layer 1's ``ir/kernel-missing``
    already fired for that).  Tracing shares the jit cache with the IR
    lint and execution, so this costs zero extra traces.
    """
    from repro.analysis import jaxpr_lint
    from repro.netsim import engine

    facts = {"kernel_checked": False, "vmem_bytes_per_step": 0}
    if jaxpr_lint.kernel_expectation(cfg, sweep) != "fused":
        return [], facts

    traced = engine.trace_sweep(cfg, sweep)
    eqns = find_kernel_eqns(traced.jaxpr)
    if not eqns:
        return [], facts

    expected = _expected_for(cfg, sweep)
    findings: list[Finding] = []
    for eqn in eqns:
        ef, efacts = lint_kernel_eqn(
            eqn, expected, label=label,
            vmem_ceiling_bytes=vmem_ceiling_bytes)
        findings.extend(ef)
        facts["vmem_bytes_per_step"] = max(facts["vmem_bytes_per_step"],
                                           efacts["vmem_bytes_per_step"])
    facts["kernel_checked"] = True
    facts["n_kernel_eqns"] = len(eqns)
    return findings, facts


def _expected_for(cfg, sweep) -> ms.KernelLayout:
    """The layout ops.py will build for this (cfg, sweep) specialization."""
    from repro.kernels import ops

    return ops.kernel_layout(
        cfg.topo.n_flows,
        use_static_factors=sweep.static_job_factors is not None)
