"""Drive the five lint layers over a plan set and assemble the report.

Per plan (and, for armed suites, per arming variant):

1. plan lint — predict compile groups, explain/judge every split;
2. IR lint — trace each predicted group's program (`engine.trace_sweep`,
   never executing) and prove kernel presence, f32-only, no callbacks, no
   stray control flow;
3. accounting — `counters.watch` around the traces cross-checks the
   prediction (``plan/group-mismatch`` when the jit cache disagrees) and a
   deliberate re-trace of group 0 proves the cache is warm afterwards
   (``plan/retrace`` otherwise);
4. kernel lint — walk each fused group's ``pallas_call`` eqn in the
   already-traced jaxpr (zero extra traces) and prove the CC-tick kernel
   body's memory-space / block / grid / body-op invariants;
5. HLO budgets (opt-in via ``budgets=``) — compile each group once and
   compare its flop/byte/memory/collective envelope against the committed
   baseline (`hlo_budget.BudgetBook`).

``expect_cold=True`` (the CLI/CI path: fresh process) hardens the
cross-check into the strict proof groups_predicted == groups_traced; in a
warm process (tests, benchmark reuse) only traces *above* the prediction
are an error — cache hits from earlier work are legitimate.
"""
from __future__ import annotations

from typing import Optional

from repro.analysis import jaxpr_lint, kernel_lint, plan_lint, source_lint
from repro.analysis.findings import AnalysisReport, make_finding
from repro.analysis.hlo_budget import BudgetBook

__all__ = ["analyze_plan", "run_analysis"]


def _analyze_variant(label: str, plan, telemetry, *, pad_jobs: bool,
                     expect_cold: bool, whitelist: frozenset,
                     report: AnalysisReport,
                     budgets: Optional[BudgetBook] = None) -> None:
    from repro.netsim import counters, engine, experiment

    findings, pfacts = plan_lint.lint_plan(
        plan, label=label, pad_jobs=pad_jobs, telemetry=telemetry)
    report.extend(findings)
    points, cfgs, overrides, groups = pfacts.pop("_resolved")

    kernel_proven = kernel_bodies = f64_total = pallas_total = 0
    vmem_peak = 0
    with counters.watch() as w:
        for gi, group in enumerate(groups):
            glabel = f"{label}/group{gi}"
            sweep = experiment.group_sweep(cfgs, overrides, group)
            gf, gfacts = jaxpr_lint.lint_sweep(
                group.cfg, sweep, label=glabel, whitelist=whitelist)
            report.extend(gf)
            f64_total += gfacts["f64_ops"]
            pallas_total += gfacts["pallas_calls"]
            if gfacts["expectation"] == "fused" and gfacts["pallas_calls"]:
                kernel_proven += 1
            kf, kfacts = kernel_lint.lint_kernel(group.cfg, sweep,
                                                 label=glabel)
            report.extend(kf)
            if kfacts["kernel_checked"]:
                kernel_bodies += 1
                vmem_peak = max(vmem_peak, kfacts["vmem_bytes_per_step"])
            if budgets is not None:
                # _group_signature alone is not unique (it omits e.g. the
                # CC variant); the group index is deterministic per plan.
                sig = f"group{gi}|{experiment._group_signature(group)}"
                budgets.observe(label, sig,
                                budget_measure(group.cfg, sweep))
    traced, fallbacks = w.traces, w.fallbacks

    if traced > len(groups):
        report.extend([make_finding(
            "plan/group-mismatch", label,
            f"predicted {len(groups)} compile group(s) but tracing them "
            f"took {traced} traces — the grouping canonicalizer merges "
            f"points the jit static signature splits")])
    elif expect_cold and traced != len(groups):
        report.extend([make_finding(
            "plan/group-mismatch", label,
            f"predicted {len(groups)} compile group(s) but a cold process "
            f"traced only {traced} — groups share a jit cache entry, so "
            f"the canonicalizer splits points it could merge")])

    if groups:
        sweep0 = experiment.group_sweep(cfgs, overrides, groups[0])
        with counters.watch() as w2:
            engine.trace_sweep(groups[0].cfg, sweep0)
        if w2.traces:
            report.extend([make_finding(
                "plan/retrace", f"{label}/group0",
                "re-tracing an already-traced group missed the jaxpr "
                "cache — something unhashable or dynamic is in the "
                "static config signature")])

    report.proofs[label] = {
        "points": len(points),
        "groups_predicted": len(groups),
        "groups_traced": traced,
        "kernel_groups_expected":
            sum(1 for g in groups
                if jaxpr_lint.kernel_expectation(
                    g.cfg, experiment.group_sweep(cfgs, overrides, g))
                == "fused"),
        "kernel_groups_proven": kernel_proven,
        "kernel_bodies_linted": kernel_bodies,
        "kernel_vmem_bytes_per_step": vmem_peak,
        "pallas_calls": pallas_total,
        "f64_ops": f64_total,
        "kernel_fallbacks": fallbacks,
        "wasted_traces_estimate": pfacts["wasted_traces_estimate"],
    }


# Indirection so tests can monkeypatch the expensive compile step without
# stubbing XLA itself.
def budget_measure(cfg, sweep) -> dict:
    from repro.analysis import hlo_budget
    return hlo_budget.measure_group(cfg, sweep)


def analyze_plan(name: str, plan, *, telemetry=None, lint_unarmed=False,
                 pad_jobs: bool = True, expect_cold: bool = False,
                 whitelist: frozenset = frozenset(),
                 report: AnalysisReport = None,
                 budgets: Optional[BudgetBook] = None) -> AnalysisReport:
    """All per-plan static proofs for one plan; returns/extends the
    report.  Pass a `BudgetBook` to also measure + ledger each group's
    cost envelope (the caller `finish()`es or `save()`s the book)."""
    if report is None:
        report = AnalysisReport()
    variants = [(name, telemetry)]
    if telemetry is not None and lint_unarmed:
        variants.append((f"{name}[unarmed]", None))
    for label, telem in variants:
        _analyze_variant(label, plan, telem, pad_jobs=pad_jobs,
                         expect_cold=expect_cold, whitelist=whitelist,
                         report=report, budgets=budgets)
    return report


def run_analysis(plan_names=(), *, source: bool = True,
                 expect_cold: bool = False, profile: Optional[str] = None,
                 budgets: Optional[BudgetBook] = None) -> AnalysisReport:
    """The CLI entry: named plans (registry) + the source lint.

    ``profile`` stamps the report's severity profile (ci/bench/notebook);
    ``budgets`` arms layer 5 — in check mode its findings land in the
    report, in update mode the caller `save()`s afterwards.
    """
    from repro.analysis import plans as plan_registry

    report = AnalysisReport(profile=profile)
    for name in plan_names:
        plan, telemetry, lint_unarmed = plan_registry.resolve_entry(name)
        analyze_plan(name, plan, telemetry=telemetry,
                     lint_unarmed=lint_unarmed, expect_cold=expect_cold,
                     report=report, budgets=budgets)
    if budgets is not None and not budgets.update:
        report.extend(budgets.finish())
    if source:
        findings, facts = source_lint.lint_paths()
        report.extend(findings)
        report.proofs["source"] = facts
    return report
