"""Drive the three lint layers over a plan set and assemble the report.

Per plan (and, for armed suites, per arming variant):

1. plan lint — predict compile groups, explain/judge every split;
2. IR lint — trace each predicted group's program (`engine.trace_sweep`,
   never executing) and prove kernel presence, f32-only, no callbacks, no
   stray control flow;
3. accounting — `counters.watch` around the traces cross-checks the
   prediction (``plan/group-mismatch`` when the jit cache disagrees) and a
   deliberate re-trace of group 0 proves the cache is warm afterwards
   (``plan/retrace`` otherwise).

``expect_cold=True`` (the CLI/CI path: fresh process) hardens the
cross-check into the strict proof groups_predicted == groups_traced; in a
warm process (tests, benchmark reuse) only traces *above* the prediction
are an error — cache hits from earlier work are legitimate.
"""
from __future__ import annotations

from repro.analysis import jaxpr_lint, plan_lint, source_lint
from repro.analysis.findings import AnalysisReport, make_finding

__all__ = ["analyze_plan", "run_analysis"]


def _analyze_variant(label: str, plan, telemetry, *, pad_jobs: bool,
                     expect_cold: bool, whitelist: frozenset,
                     report: AnalysisReport) -> None:
    from repro.netsim import counters, engine, experiment

    findings, pfacts = plan_lint.lint_plan(
        plan, label=label, pad_jobs=pad_jobs, telemetry=telemetry)
    report.extend(findings)
    points, cfgs, overrides, groups = pfacts.pop("_resolved")

    kernel_proven = f64_total = pallas_total = 0
    with counters.watch() as w:
        for gi, group in enumerate(groups):
            sweep = experiment.group_sweep(cfgs, overrides, group)
            gf, gfacts = jaxpr_lint.lint_sweep(
                group.cfg, sweep, label=f"{label}/group{gi}",
                whitelist=whitelist)
            report.extend(gf)
            f64_total += gfacts["f64_ops"]
            pallas_total += gfacts["pallas_calls"]
            if gfacts["expectation"] == "fused" and gfacts["pallas_calls"]:
                kernel_proven += 1
    traced, fallbacks = w.traces, w.fallbacks

    if traced > len(groups):
        report.extend([make_finding(
            "plan/group-mismatch", label,
            f"predicted {len(groups)} compile group(s) but tracing them "
            f"took {traced} traces — the grouping canonicalizer merges "
            f"points the jit static signature splits")])
    elif expect_cold and traced != len(groups):
        report.extend([make_finding(
            "plan/group-mismatch", label,
            f"predicted {len(groups)} compile group(s) but a cold process "
            f"traced only {traced} — groups share a jit cache entry, so "
            f"the canonicalizer splits points it could merge")])

    if groups:
        sweep0 = experiment.group_sweep(cfgs, overrides, groups[0])
        with counters.watch() as w2:
            engine.trace_sweep(groups[0].cfg, sweep0)
        if w2.traces:
            report.extend([make_finding(
                "plan/retrace", f"{label}/group0",
                "re-tracing an already-traced group missed the jaxpr "
                "cache — something unhashable or dynamic is in the "
                "static config signature")])

    report.proofs[label] = {
        "points": len(points),
        "groups_predicted": len(groups),
        "groups_traced": traced,
        "kernel_groups_expected":
            sum(1 for g in groups
                if jaxpr_lint.kernel_expectation(
                    g.cfg, experiment.group_sweep(cfgs, overrides, g))
                == "fused"),
        "kernel_groups_proven": kernel_proven,
        "pallas_calls": pallas_total,
        "f64_ops": f64_total,
        "kernel_fallbacks": fallbacks,
        "wasted_traces_estimate": pfacts["wasted_traces_estimate"],
    }


def analyze_plan(name: str, plan, *, telemetry=None, lint_unarmed=False,
                 pad_jobs: bool = True, expect_cold: bool = False,
                 whitelist: frozenset = frozenset(),
                 report: AnalysisReport = None) -> AnalysisReport:
    """All three static proofs for one plan; returns/extends the report."""
    if report is None:
        report = AnalysisReport()
    variants = [(name, telemetry)]
    if telemetry is not None and lint_unarmed:
        variants.append((f"{name}[unarmed]", None))
    for label, telem in variants:
        _analyze_variant(label, plan, telem, pad_jobs=pad_jobs,
                         expect_cold=expect_cold, whitelist=whitelist,
                         report=report)
    return report


def run_analysis(plan_names=(), *, source: bool = True,
                 expect_cold: bool = False) -> AnalysisReport:
    """The CLI entry: named plans (registry) + the source lint."""
    from repro.analysis import plans as plan_registry

    report = AnalysisReport()
    for name in plan_names:
        plan, telemetry, lint_unarmed = plan_registry.resolve_entry(name)
        analyze_plan(name, plan, telemetry=telemetry,
                     lint_unarmed=lint_unarmed, expect_cold=expect_cold,
                     report=report)
    if source:
        findings, facts = source_lint.lint_paths()
        report.extend(findings)
        report.proofs["source"] = facts
    return report
