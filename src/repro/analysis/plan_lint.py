"""Plan lint: predict a plan's compile groups and explain every split.

Reuses `experiment.resolve_plan` — the *same* canonicalization + bucketing
`run_plan` executes — so the prediction is the execution, minus the run.
For each pair of predicted groups the linter diffs their canonical static
configs field-by-field and emits:

* ``plan/group-split`` (info): the exact canonicalized field paths that
  differ — no split is ever unexplained;
* ``plan/avoidable-split`` (warning): every differing field is a plain
  numeric value (not structural — not a shape, flag, enum or string), i.e.
  it could ride the batched sweep as a traced `SweepParams` leaf the way
  PR 4 moved workload values and straggle probabilities; the finding
  carries the wasted-trace estimate (extra compile groups that would merge
  if those fields were swept dynamically).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.analysis.findings import Finding, make_finding

__all__ = ["lint_plan", "predict_compile_groups", "STRUCTURAL_FIELDS"]

# Field basenames that legitimately change the traced program's structure
# (static shapes, enum dispatch, python-level branches in the engine).
# Splits on anything *outside* this set are flagged avoidable.
STRUCTURAL_FIELDS = frozenset({
    # engine structure
    "sim_time", "dt", "n_chunks", "max_iters_recorded", "telemetry",
    "use_pallas_kernel", "cubic_epoch_reset_on_comm_start", "seed",
    # protocol dispatch
    "algo", "variant", "f_spec", "favoritism", "aggregate_by_job",
    "ecn_mode", "rtt", "tick_dt", "mss",
    # workload / fabric shape
    "n_jobs", "n_flows", "n_phases", "sockets_per_job",
    # fault-injection structure (netsim.faults.FaultSpec: the event-table
    # row count and armed channels shape the traced program; schedule
    # *values* ride the sweep and never appear in canonical configs)
    "faults", "n_events", "churn", "link_flaps", "blackholes",
    "straggle_bursts",
})


def _leaf_diffs(a, b, path: str, out: list) -> None:
    if a is b:
        return
    if type(a) is not type(b):
        out.append((path, a, b))
        return
    if dataclasses.is_dataclass(a) and not isinstance(a, type):
        for f in dataclasses.fields(a):
            sub = f"{path}.{f.name}" if path else f.name
            _leaf_diffs(getattr(a, f.name), getattr(b, f.name), sub, out)
        return
    if isinstance(a, tuple) and hasattr(a, "_fields"):   # NamedTuple
        for fname in a._fields:
            sub = f"{path}.{fname}" if path else fname
            _leaf_diffs(getattr(a, fname), getattr(b, fname), sub, out)
        return
    if isinstance(a, (list, tuple)):
        if len(a) != len(b):
            out.append((path + ".len", len(a), len(b)))
            return
        for i, (x, y) in enumerate(zip(a, b)):
            _leaf_diffs(x, y, f"{path}[{i}]", out)
        return
    if isinstance(a, np.ndarray):
        if a.shape != b.shape or a.dtype != b.dtype:
            out.append((path, f"{a.dtype}{list(a.shape)}",
                        f"{b.dtype}{list(b.shape)}"))
        elif not np.array_equal(a, b):
            out.append((path, "<array values>", "<array values>"))
        return
    if a != b:
        out.append((path, a, b))


def _short(v) -> str:
    s = repr(v)
    return s if len(s) <= 40 else s[:37] + "..."


def _basename(path: str) -> str:
    return path.split(".")[-1].split("[")[0]


def _is_avoidable(path: str, va, vb) -> bool:
    """A diff a traced SweepParams leaf could absorb: plain numeric value,
    non-structural name, identical shapes."""
    if _basename(path) in STRUCTURAL_FIELDS:
        return False
    for v in (va, vb):
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            return False
    return True


def predict_compile_groups(plan, *, pad_jobs: bool = True,
                           telemetry=None) -> int:
    """How many programs `run_plan` will trace for this plan (cold cache)."""
    from repro.netsim import experiment

    _, _, _, groups = experiment.resolve_plan(
        plan, pad_jobs=pad_jobs, telemetry=telemetry)
    return len(groups)


def lint_plan(plan, *, label: str, pad_jobs: bool = True,
              telemetry=None) -> tuple[list[Finding], dict]:
    """Explain (and judge) a plan's compile-group structure.

    Returns ``(findings, facts)``; facts also hand back the resolved
    ``(points, cfgs, overrides, groups)`` so the runner lints each group's
    lowering without re-resolving the plan.
    """
    from repro.netsim import experiment

    points, cfgs, overrides, groups = experiment.resolve_plan(
        plan, pad_jobs=pad_jobs, telemetry=telemetry)
    findings: list[Finding] = []

    # Pairwise split explainers.  G is small (a handful of groups per
    # figure suite); O(G^2) diffs of canonical configs are trivial next to
    # one trace.
    mergeable_with: dict[int, int] = {}      # union-find over groups
    def find(i: int) -> int:
        while mergeable_with.get(i, i) != i:
            i = mergeable_with[i]
        return i

    for gi in range(len(groups)):
        for gj in range(gi + 1, len(groups)):
            diffs: list = []
            _leaf_diffs(groups[gi].cfg, groups[gj].cfg, "", diffs)
            if not diffs:
                # same canonical cfg, split by factor-presence or shape
                # merge heuristics — explain via the group flags
                diffs = [("static_job_factors.presence",
                          groups[gi].factors, groups[gj].factors)]
            detail = "; ".join(f"{p}: {_short(va)} != {_short(vb)}"
                               for p, va, vb in diffs[:6])
            if len(diffs) > 6:
                detail += f"; ... {len(diffs) - 6} more"
            findings.append(make_finding(
                "plan/group-split", f"{label}/group{gi}~group{gj}",
                f"{len(diffs)} canonical field(s) differ: {detail}"))
            if diffs and all(_is_avoidable(p, va, vb) for p, va, vb in diffs):
                findings.append(make_finding(
                    "plan/avoidable-split", f"{label}/group{gi}~group{gj}",
                    f"split only on value-like field(s) "
                    f"{sorted({_basename(p) for p, _, _ in diffs})} — these "
                    f"could be traced SweepParams leaves; merging would "
                    f"save one trace+compile"))
                ri, rj = find(gi), find(gj)
                if ri != rj:
                    mergeable_with[max(ri, rj)] = min(ri, rj)

    wasted = sum(1 for g in range(len(groups)) if find(g) != g)
    facts = {
        "points": len(points), "groups": len(groups),
        "wasted_traces_estimate": wasted,
        "_resolved": (points, cfgs, overrides, groups),
    }
    return findings, facts
