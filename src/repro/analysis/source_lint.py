"""Source lint: AST rules over the engine/core/kernel packages.

The IR lint proves properties of programs we can trace; this layer catches
the bug *patterns* in the Python source itself, including code paths no CI
plan exercises.  It builds a light call graph per run:

1. parse every module under the scan roots, recording import aliases,
   function definitions (methods and nested defs included) and, per
   function, local bindings of callables (``tick_fn = core.cc_tick``,
   ``tick = partial(_tick, ...)``);
2. find every ``lax.scan`` / ``fori_loop`` / ``while_loop`` / ``cond``
   call and resolve its body argument(s) to project functions — the *loop
   roots*;
3. BFS the call graph from the roots: everything reached is
   *scan-reachable*, i.e. runs inside traced loop bodies every tick.

Rules then split by context.  Scan-reachable functions must not call
``np.*`` (``src/np-in-scan``) or touch float64 (``src/f64-literal`` for
``np.float64`` / ``"float64"``); ``jnp.float64`` is flagged everywhere.
Everywhere we flag ``float()/int()/bool()`` on values inferred traced
(``src/float-cast-traced``), python ``if`` on traced values
(``src/branch-on-traced``) and unit-suffix conflicts in arithmetic and
comparisons (``src/unit-suffix``: ``_bytes`` vs ``_s`` vs ``_bps`` vs
``_ticks``).

False-positive escape hatch: an inline pragma on the offending line —
``# lint: allow(np-in-scan)`` — suppresses that rule for that line (the
one legitimate case in-tree is telemetry's trace-time-static
``np.triu_indices`` pair index; see DESIGN.md §7).  Pragmas are audited
in turn: after all rule passes run, any pragma naming an unknown rule id,
or one that suppressed nothing on its line, raises ``src/stale-pragma``
so suppressions cannot outlive the code they excused.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Optional

from repro.analysis.findings import Finding, make_finding

__all__ = ["lint_paths", "lint_sources", "DEFAULT_SCAN_DIRS"]

# Packages whose sources the default lint run scans.
DEFAULT_SCAN_DIRS = ("repro/core", "repro/netsim", "repro/kernels")

_PRAGMA = re.compile(r"#\s*lint:\s*allow\(([a-z0-9/_-]+(?:\s*,\s*[a-z0-9/_-]+)*)\)")

# jax-ish roots: calls on these produce traced values / host loop bodies.
_JAX_MODULES = ("jax", "jax.numpy", "jax.lax")
_NUMPY_MODULES = ("numpy",)

# loop primitive -> positional indices of its function-valued args
_LOOP_BODY_ARGS = {
    "scan": (0,), "fori_loop": (2,), "while_loop": (0, 1),
    "cond": (1, 2), "switch": None,   # switch: all args from 1 on
}

_UNIT_SUFFIXES = (("_bytes_per_s", "bps"), ("_bps", "bps"),
                  ("_bytes", "bytes"), ("_ticks", "ticks"), ("_s", "s"))


def _unit_of(name: str) -> Optional[str]:
    for suf, unit in _UNIT_SUFFIXES:
        if name.endswith(suf):
            return unit
    return None


@dataclasses.dataclass
class _Module:
    name: str                                 # dotted, e.g. repro.core.mltcp
    filename: str                             # display path for findings
    tree: ast.Module
    lines: list[str]
    imports: dict = dataclasses.field(default_factory=dict)       # alias -> module
    from_imports: dict = dataclasses.field(default_factory=dict)  # name -> (mod, orig)
    functions: dict = dataclasses.field(default_factory=dict)     # qual -> node
    pragma_hits: set = dataclasses.field(default_factory=set)     # (lineno, token)


def _module_name(path: Path) -> str:
    parts = list(path.with_suffix("").parts)
    if "src" in parts:
        parts = parts[parts.index("src") + 1:]
    # keep at most the package-relative tail
    for root in ("repro",):
        if root in parts:
            parts = parts[parts.index(root):]
            break
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) or path.stem


def _collect_module(name: str, filename: str, source: str) -> _Module:
    mod = _Module(name=name, filename=filename,
                  tree=ast.parse(source), lines=source.splitlines())
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                mod.imports[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                mod.from_imports[a.asname or a.name] = (node.module, a.name)

    def visit(node, qual):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{qual}.{child.name}" if qual else child.name
                mod.functions[q] = child
                visit(child, q)
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{qual}.{child.name}" if qual else child.name)
            else:
                visit(child, qual)

    visit(mod.tree, "")
    return mod


class _Index:
    """Cross-module function index + best-effort call resolution."""

    def __init__(self, modules: list[_Module]):
        self.modules = {m.name: m for m in modules}
        # global key "modname:qual" -> function node
        self.table = {}
        for m in modules:
            for q, node in m.functions.items():
                self.table[f"{m.name}:{q}"] = node

    def _project_key(self, modname: str, fn: str) -> Optional[str]:
        """Resolve (module-ish name, function) to a table key, following
        package re-exports (repro.core:cc_tick -> repro.core.mltcp:cc_tick)."""
        key = f"{modname}:{fn}"
        if key in self.table:
            return key
        prefix = modname + "."
        for m in self.modules.values():
            if m.name.startswith(prefix) and fn in m.functions:
                return f"{m.name}:{fn}"
        return None

    def _root_module(self, mod: _Module, alias: str) -> Optional[str]:
        if alias in mod.imports:
            return mod.imports[alias]
        if alias in mod.from_imports:
            src, orig = mod.from_imports[alias]
            return f"{src}.{orig}"      # `from repro.netsim import telemetry`
        return None

    def is_jaxish(self, mod: _Module, alias: str) -> bool:
        tgt = self._root_module(mod, alias)
        return tgt is not None and (tgt in _JAX_MODULES
                                    or tgt.startswith("jax."))

    def is_numpy(self, mod: _Module, alias: str) -> bool:
        tgt = self._root_module(mod, alias)
        return tgt in _NUMPY_MODULES

    def resolve_callable(self, mod: _Module, qual: str, expr,
                         bindings: dict) -> set:
        """Project-function keys an expression may denote (empty if none).

        Handles: bare names (local bindings -> enclosing nested defs ->
        module functions -> from-imports), ``mod.attr`` on imported project
        modules, and ``partial(f, ...)``.
        """
        if isinstance(expr, ast.Name):
            n = expr.id
            if n in bindings:
                return set(bindings[n])
            # nested def in the enclosing function chain
            scope = qual
            while scope:
                q = f"{scope}.{n}"
                if q in mod.functions:
                    return {f"{mod.name}:{q}"}
                scope = scope.rpartition(".")[0]
            if n in mod.functions:
                return {f"{mod.name}:{n}"}
            if n in mod.from_imports:
                src, orig = mod.from_imports[n]
                key = self._project_key(src, orig)
                return {key} if key else set()
            return set()
        if isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name):
            tgt = self._root_module(mod, expr.value.id)
            if tgt is not None:
                key = self._project_key(tgt, expr.attr)
                return {key} if key else set()
            return set()
        if isinstance(expr, ast.Call):
            fn = expr.func
            is_partial = (
                (isinstance(fn, ast.Name) and fn.id == "partial")
                or (isinstance(fn, ast.Attribute) and fn.attr == "partial"))
            if is_partial and expr.args:
                return self.resolve_callable(mod, qual, expr.args[0], bindings)
        return set()


def _local_bindings(index: _Index, mod: _Module, qual: str,
                    fn: ast.FunctionDef) -> dict:
    """name -> set of project-function keys it may be bound to (union over
    reassignments, so ``tick_fn = core.cc_tick`` / ``tick_fn = ops.mltcp_cc_tick``
    yields both)."""
    bindings: dict = {}
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign):
            continue
        keys = index.resolve_callable(mod, qual, node.value, bindings)
        if not keys:
            continue
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                bindings.setdefault(tgt.id, set()).update(keys)
    return bindings


def _loop_roots(index: _Index, mod: _Module) -> set:
    """Project-function keys used as loop bodies anywhere in this module."""
    roots: set = set()
    scopes = [("", mod.tree)] + list(mod.functions.items())
    for qual, scope in scopes:
        bindings = (_local_bindings(index, mod, qual, scope)
                    if isinstance(scope, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)) else {})
        for node in ast.walk(scope):
            if not isinstance(node, ast.Call):
                continue
            fname = None
            if isinstance(node.func, ast.Attribute):
                root = node.func
                while isinstance(root, ast.Attribute):
                    base, root = root, root.value
                if (isinstance(root, ast.Name)
                        and (index.is_jaxish(mod, root.id)
                             or root.id in ("jax", "lax"))):
                    fname = node.func.attr
            elif isinstance(node.func, ast.Name):
                src = mod.from_imports.get(node.func.id, ("", ""))[0]
                if src.startswith("jax"):
                    fname = mod.from_imports[node.func.id][1]
            if fname not in _LOOP_BODY_ARGS:
                continue
            arg_ix = _LOOP_BODY_ARGS[fname]
            if arg_ix is None:                       # switch: branches 1..N
                arg_ix = tuple(range(1, len(node.args)))
            for i in arg_ix:
                if i < len(node.args):
                    roots |= index.resolve_callable(mod, qual, node.args[i],
                                                    bindings)
    return roots


def _call_edges(index: _Index, mod: _Module, qual: str,
                fn: ast.FunctionDef) -> set:
    bindings = _local_bindings(index, mod, qual, fn)
    edges: set = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            edges |= index.resolve_callable(mod, qual, node.func, bindings)
    # bound callables count as edges even when only called indirectly
    for keys in bindings.values():
        edges |= keys
    return edges


def _allowed(mod: _Module, lineno: int, rule: str) -> bool:
    if 1 <= lineno <= len(mod.lines):
        m = _PRAGMA.search(mod.lines[lineno - 1])
        if m:
            allowed = {r.strip() for r in m.group(1).split(",")}
            short = rule.split("/", 1)[-1]
            for token in (rule, short):
                if token in allowed:
                    mod.pragma_hits.add((lineno, token))
                    return True
    return False


def _lint_pragmas(modules: list, findings: list) -> int:
    """Post-pass (runs after every rule pass has recorded its
    suppressions): flag pragmas that name an unknown rule or suppressed
    nothing on their line.  Returns the pragma count."""
    from repro.analysis.findings import RULES

    known = set(RULES) | {r.split("/", 1)[-1] for r in RULES}
    n_pragmas = 0
    for mod in modules:
        for lineno, line in enumerate(mod.lines, start=1):
            m = _PRAGMA.search(line)
            if m is None:
                continue
            n_pragmas += 1
            for token in (t.strip() for t in m.group(1).split(",")):
                where = f"{mod.filename}:{lineno}"
                if token not in known:
                    findings.append(make_finding(
                        "src/stale-pragma", where,
                        f"pragma allows unknown rule {token!r} — no "
                        f"registered rule has that id or short name"))
                elif (lineno, token) not in mod.pragma_hits:
                    findings.append(make_finding(
                        "src/stale-pragma", where,
                        f"pragma allows {token!r} but no such finding "
                        f"fires on this line — the suppression has "
                        f"outlived the code it excused"))
    return n_pragmas


def _where(mod: _Module, node) -> str:
    return f"{mod.filename}:{node.lineno}"


# ---------------------------------------------------------------------------
# per-function rule passes
# ---------------------------------------------------------------------------

def _traced_names(index: _Index, mod: _Module, fn: ast.FunctionDef) -> set:
    """Names inferred to hold traced values: assigned (transitively) from a
    jnp/jax/lax call.  Parameters are *not* auto-traced — the engine's
    static-config branches (``if cfg.use_pallas_kernel``) must stay legal."""
    traced: set = set()

    def mentions_traced(expr) -> bool:
        for n in ast.walk(expr):
            if isinstance(n, ast.Name) and n.id in traced:
                return True
            if (isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)):
                root = n.func
                while isinstance(root, ast.Attribute):
                    root = root.value
                if (isinstance(root, ast.Name)
                        and index.is_jaxish(mod, root.id)):
                    return True
        return False

    def bind(tgt):
        # only plain name targets (and tuple/list unpacks of them) become
        # traced; subscript/attribute targets would leak index names
        if isinstance(tgt, ast.Name):
            traced.add(tgt.id)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for el in tgt.elts:
                bind(el)

    # two passes over statements in textual order picks up simple forward
    # chains without a full fixpoint
    for _ in range(2):
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and mentions_traced(node.value):
                for tgt in node.targets:
                    bind(tgt)
            elif isinstance(node, ast.AugAssign) and mentions_traced(node.value):
                bind(node.target)
    return traced


# attributes of traced arrays that are static python values — branching on
# them is fine
_STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "_fields"})


def _dynamic_names(expr) -> set:
    """Names in `expr` whose *values* flow into it — skipping `is`/`is not`
    comparisons (None-ness is static) and static array attributes."""
    out: set = set()

    def rec(n):
        if (isinstance(n, ast.Compare)
                and all(isinstance(o, (ast.Is, ast.IsNot)) for o in n.ops)):
            return
        if isinstance(n, ast.Attribute) and n.attr in _STATIC_ATTRS:
            return
        if isinstance(n, ast.Name):
            out.add(n.id)
        for c in ast.iter_child_nodes(n):
            rec(c)

    rec(expr)
    return out


def _lint_function(index: _Index, mod: _Module, qual: str,
                   fn: ast.FunctionDef, reachable: bool,
                   findings: list) -> None:
    traced = _traced_names(index, mod, fn)

    def emit(rule, node, msg):
        if not _allowed(mod, node.lineno, rule):
            findings.append(make_finding(rule, _where(mod, node), msg))

    def np_root(expr) -> bool:
        root = expr
        while isinstance(root, ast.Attribute):
            root = root.value
        return (isinstance(root, ast.Name)
                and (index.is_numpy(mod, root.id) or root.id == "np"))

    own_defs = {n for n in ast.walk(fn)
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                and n is not fn}
    skip = set()
    for d in own_defs:       # nested defs are linted as their own qualnames
        skip.update(ast.walk(d))

    for node in ast.walk(fn):
        if node in skip:
            continue
        if isinstance(node, ast.Call):
            f = node.func
            if reachable and isinstance(f, ast.Attribute) and np_root(f):
                emit("src/np-in-scan", node,
                     f"`{ast.unparse(f)}` call in scan-reachable "
                     f"`{mod.name}:{qual}` (np.* does not trace; whitelist "
                     f"trace-time constants with `# lint: allow(np-in-scan)`)")
            if (reachable and isinstance(f, ast.Name)
                    and f.id in ("float", "int", "bool")
                    and len(node.args) == 1):
                arg = node.args[0]
                if _dynamic_names(arg) & traced:
                    emit("src/float-cast-traced", node,
                         f"`{f.id}({ast.unparse(arg)})` concretizes a "
                         f"traced value in `{mod.name}:{qual}`")
        elif isinstance(node, ast.If):
            if reachable and _dynamic_names(node.test) & traced:
                emit("src/branch-on-traced", node,
                     f"python `if {ast.unparse(node.test)}` on a traced "
                     f"value in `{mod.name}:{qual}`; use jnp.where/lax.cond")
        elif isinstance(node, ast.Attribute) and node.attr == "float64":
            if np_root(node):
                if reachable:
                    emit("src/f64-literal", node,
                         f"np.float64 in scan-reachable `{mod.name}:{qual}` "
                         f"(numpy-side plumbing only)")
            else:
                root = node.value
                while isinstance(root, ast.Attribute):
                    root = root.value
                if (isinstance(root, ast.Name)
                        and index.is_jaxish(mod, root.id)):
                    emit("src/f64-literal", node,
                         f"jnp/jax float64 in `{mod.name}:{qual}` — the "
                         f"pipeline is pinned f32")
        elif (reachable and isinstance(node, ast.Constant)
                and node.value == "float64"):
            emit("src/f64-literal", node,
                 f'"float64" dtype string in scan-reachable '
                 f"`{mod.name}:{qual}`")


def _operand_unit(expr) -> Optional[str]:
    if isinstance(expr, ast.Name):
        return _unit_of(expr.id)
    if isinstance(expr, ast.Attribute):
        return _unit_of(expr.attr)
    return None


def _lint_units(mod: _Module, findings: list) -> None:
    for node in ast.walk(mod.tree):
        pairs = []
        if isinstance(node, ast.BinOp) and isinstance(node.op,
                                                      (ast.Add, ast.Sub)):
            pairs = [(node.left, node.right)]
        elif isinstance(node, ast.Compare):
            operands = [node.left] + list(node.comparators)
            pairs = list(zip(operands, operands[1:]))
        for a, b in pairs:
            ua, ub = _operand_unit(a), _operand_unit(b)
            if ua and ub and ua != ub:
                if not _allowed(mod, node.lineno, "src/unit-suffix"):
                    findings.append(make_finding(
                        "src/unit-suffix", _where(mod, node),
                        f"`{ast.unparse(a)}` [{ua}] "
                        f"{'+/-' if isinstance(node, ast.BinOp) else 'vs'} "
                        f"`{ast.unparse(b)}` [{ub}] mixes units"))


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def _lint_modules(modules: list[_Module]) -> tuple[list[Finding], dict]:
    index = _Index(modules)
    roots: set = set()
    for m in modules:
        roots |= _loop_roots(index, m)

    # BFS the call graph from the loop roots
    reachable = set(roots)
    frontier = list(roots)
    while frontier:
        key = frontier.pop()
        node = index.table.get(key)
        if node is None:
            continue
        modname, qual = key.split(":", 1)
        for callee in _call_edges(index, index.modules[modname], qual, node):
            if callee not in reachable:
                reachable.add(callee)
                frontier.append(callee)

    findings: list[Finding] = []
    for m in modules:
        for qual, fn in m.functions.items():
            _lint_function(index, m, qual, fn,
                           reachable=f"{m.name}:{qual}" in reachable,
                           findings=findings)
        _lint_units(m, findings)
    n_pragmas = _lint_pragmas(modules, findings)

    facts = {"modules": len(modules),
             "functions": len(index.table),
             "loop_roots": len(roots),
             "scan_reachable": len(reachable),
             "pragmas": n_pragmas}
    return findings, facts


def lint_sources(sources: dict) -> tuple[list[Finding], dict]:
    """Lint in-memory sources: {filename: text}.  Module names derive from
    the filenames (`a/b.py` -> `a.b`), so fixtures can fake cross-module
    imports.  This is the test surface."""
    modules = [_collect_module(_module_name(Path(fname)), fname, text)
               for fname, text in sorted(sources.items())]
    return _lint_modules(modules)


def lint_paths(paths=None) -> tuple[list[Finding], dict]:
    """Lint the repo sources (default: core, netsim, kernels packages)."""
    if paths is None:
        src_root = Path(__file__).resolve().parents[2]
        paths = [src_root / d for d in DEFAULT_SCAN_DIRS]
    files: list[Path] = []
    for p in map(Path, paths):
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    modules = []
    for f in files:
        try:
            rel = f.resolve().relative_to(Path.cwd())
        except ValueError:
            rel = f
        modules.append(_collect_module(_module_name(f), str(rel),
                                       f.read_text()))
    return _lint_modules(modules)
