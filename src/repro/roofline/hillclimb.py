import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512")

"""Perf hillclimb harness (EXPERIMENTS.md §Perf).

Three cells chosen from the baseline roofline table:
  A. qwen1.5-4b  x train_4k   — worst roofline fraction (memory term blows up
     because 20 heads can't shard on the 16-way model axis; every device
     materializes all-head [T, S] attention probs),
  B. qwen3-1.7b  x decode_32k — most collective-bound (the GQA head repeat
     makes the partitioner all-gather the whole KV cache every step),
  C. qwen3-1.7b  x train_4k   — representative of the paper's subject
     (data-parallel training whose gradient/activation collectives are the
     traffic MLTCP schedules).

Each experiment: hypothesis -> change -> re-lower -> measure -> verdict,
appended to results/hillclimb.json.
"""
import json

from repro.models import attention, moe
from repro.roofline.analysis import roofline_cell
from repro.train.sharding import ShardingRules


def _delta(base, new, key):
    b, n = base[key], new[key]
    return f"{b * 1e3:.1f}ms -> {n * 1e3:.1f}ms ({b / max(n, 1e-12):.2f}x)"


def experiment(records, name, arch, shape, hypothesis, rules=None,
               seq_shard=None, grouped_gqa=None, dispatch=None,
               ep_axis=None, baseline=None):
    prev_axis = attention.SEQ_SHARD_AXIS
    prev_gqa = attention.DECODE_GROUPED_GQA
    prev_disp = moe.DISPATCH_MODE
    prev_ep = moe.EP_CONSTRAINT_AXIS
    if seq_shard is not None:
        attention.SEQ_SHARD_AXIS = seq_shard
    if grouped_gqa is not None:
        attention.DECODE_GROUPED_GQA = grouped_gqa
    if dispatch is not None:
        moe.DISPATCH_MODE = dispatch
    if ep_axis is not None:
        moe.EP_CONSTRAINT_AXIS = ep_axis
    try:
        rec = roofline_cell(arch, shape, rules=rules, label=name)
    finally:
        attention.SEQ_SHARD_AXIS = prev_axis
        attention.DECODE_GROUPED_GQA = prev_gqa
        moe.DISPATCH_MODE = prev_disp
        moe.EP_CONSTRAINT_AXIS = prev_ep
    rec["hypothesis"] = hypothesis
    if baseline is not None and rec.get("status") == "ok":
        dom = baseline["dominant"]
        key = f"t_{dom}_s"
        rec["dominant_term_delta"] = _delta(baseline, rec, key)
        rec["bound_delta"] = _delta(baseline, rec, "roofline_bound_s")
        print(f"    => {name}: dominant({dom}) {rec['dominant_term_delta']}")
    records.append(rec)
    return rec


def main():
    records = []

    # =====================================================================
    # Cell A: qwen1.5-4b x train_4k (worst roofline fraction, memory-bound)
    # =====================================================================
    print("=== Cell A: qwen1.5-4b train_4k ===")
    a0 = experiment(
        records, "A0-baseline", "qwen1.5-4b", "train_4k",
        "baseline: dh-sharded attention (20 heads % 16 != 0) leaves all-head "
        "[B,T,S] probs per device; expect memory-dominated",
        grouped_gqa=False)
    experiment(
        records, "A1-seq-parallel-attn", "qwen1.5-4b", "train_4k",
        "napkin: probs bytes ~ B*H*T*S*4 per device; sharding the query/"
        "sequence axis of the scores over the 16-way model axis divides the "
        "dominant bytes term by ~16 at the cost of one KV all-gather per "
        "layer (~B*S*K*dh*2 bytes, ~100x smaller)",
        seq_shard="model", grouped_gqa=False, baseline=a0)

    # =====================================================================
    # Cell B: qwen3-1.7b x decode_32k (most collective-bound)
    # =====================================================================
    print("=== Cell B: qwen3-1.7b decode_32k ===")
    b0 = experiment(
        records, "B0-baseline", "qwen3-1.7b", "decode_32k",
        "baseline: jnp.repeat KV-head expansion gathers the 2 GiB KV cache "
        "per decoded token; expect collective-dominated",
        grouped_gqa=False)
    b1 = experiment(
        records, "B1-grouped-gqa", "qwen3-1.7b", "decode_32k",
        "napkin: grouped einsum q[B,1,K,g,dh] x cache[B,S,K,dh] needs no "
        "expanded KV; the only collective left should be the psum over the "
        "dh-sharded contraction (~B*H*S*4 bytes, ~1000x less than the cache)",
        grouped_gqa=True, baseline=b0)
    # B1 verdict: CONFIRMED direction but only 2x — the dh-sharded cache
    # still forces partial gathers. Revised: shard the cache on its
    # *sequence* axis (context-parallel decode): each model rank holds
    # 1/16th of the context; only the [B,H,S] scores cross devices.
    experiment(
        records, "B2-seq-sharded-cache", "qwen3-1.7b", "decode_32k",
        "napkin: seq-sharded cache leaves per-step collectives ~ scores "
        "(B*H*S*4 ~ 270 MB) + psum of out (~B*H*dh, KB) instead of "
        "cache-sized gathers; expect another >=2x on the collective term",
        grouped_gqa=True,
        rules=ShardingRules(data_axes=("data",), decode_cache_seq_shard=True),
        baseline=b1)

    # =====================================================================
    # Cell C: qwen3-1.7b x train_4k (the paper's own workload shape)
    # =====================================================================
    print("=== Cell C: qwen3-1.7b train_4k ===")
    c0 = experiment(
        records, "C0-baseline", "qwen3-1.7b", "train_4k",
        "baseline: 16-way tensor parallelism all-reduces every layer's "
        "activations fwd+bwd (~4*B*T*D*28 bytes >> the 1.7B model's own "
        "gradients); expect collective/memory-bound",
        grouped_gqa=False)
    fsdp = ShardingRules(fsdp=True, data_axes=("data",))
    c1 = experiment(
        records, "C1-fsdp-over-tp", "qwen3-1.7b", "train_4k",
        "napkin: adding data-sharding to the TP weights (ZeRO on top of TP) "
        "— prediction: ~4x collective reduction from replacing activation "
        "ARs with weight AGs",
        rules=fsdp, baseline=c0)
    c2 = experiment(
        records, "C2-fsdp+seq-attn", "qwen3-1.7b", "train_4k",
        "stack A1's sequence-parallel attention on top of C1 to also cut "
        "the memory term (probs sharded 16-way)",
        rules=fsdp, seq_shard="model", baseline=c1)
    # C1 verdict: REFUTED — ZeRO on top of TP leaves the dominant
    # activation all-reduces untouched. Revised hypothesis: the TP itself
    # is the problem for a 1.7B model; go *pure* FSDP (no model-sharded
    # weights; all 256 chips act as data shards, batch 1/device).
    pure_fsdp = ShardingRules(fsdp=True, tensor_parallel=False,
                              data_axes=("data", "model"))
    experiment(
        records, "C3-pure-fsdp", "qwen3-1.7b", "train_4k",
        "napkin: pure FSDP moves 3x params/step (2 AG + 1 RS ~ 20 GB "
        "global, ~80 MB/device) vs TP's ~150 GB/device activation ARs; "
        "expect the collective term to collapse by >10x",
        rules=pure_fsdp, baseline=c0)

    # =====================================================================
    # Cell D (beyond the required three): deepseek-moe-16b x train_4k —
    # the MoE-dispatch pathology surfaced by the baseline table
    # =====================================================================
    print("=== Cell D: deepseek-moe-16b train_4k (MoE dispatch) ===")
    d0 = experiment(
        records, "D0-baseline-cumsum-dispatch", "deepseek-moe-16b",
        "train_4k",
        "baseline: one-hot cumsum dispatch builds an [N*k, E] intermediate "
        "and O(N*E) prefix work per MoE layer at N=1M tokens; expect it to "
        "dominate all three terms",
        dispatch="cumsum", grouped_gqa=False)
    d1 = experiment(
        records, "D1-sort-dispatch", "deepseek-moe-16b", "train_4k",
        "napkin: stable argsort dispatch is O(N*k log N*k) with no [N, E] "
        "intermediate; expert matmuls (top_k*N*3*2*d*de*cf ~ 1.3e14/layer) "
        "should become the dominant compute; expect >10x drop in the "
        "memory/compute terms",
        dispatch="sort", grouped_gqa=False, baseline=d0)
    # D1 verdict: CONFIRMED on compute (9.7x) — but the collective term is
    # untouched: GSPMD replicates the [E, C, d] expert buffer and
    # all-reduces it every layer. Revised: pin the buffer to the expert-
    # parallel axis with an explicit sharding constraint.
    experiment(
        records, "D2-sort+ep-constraint", "deepseek-moe-16b", "train_4k",
        "napkin: constraining eb/out to P('model', ...) turns the buffer "
        "all-reduce (~30 GB/layer) into a dispatch all-to-all (~N*d*2 "
        "bytes ~ 4 GB/layer global); expect >5x on the collective term",
        dispatch="sort", ep_axis="model", grouped_gqa=False, baseline=d1)

    os.makedirs("results", exist_ok=True)
    with open("results/hillclimb.json", "w") as f:
        json.dump(records, f, indent=1)
    print("wrote results/hillclimb.json")


if __name__ == "__main__":
    main()
