import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512")

"""Roofline analysis (deliverable g).

Per (arch x shape) on the single-pod mesh, derive the three roofline terms:

  compute    = HLO_FLOPs_per_device            / peak_FLOP/s        (197e12)
  memory     = HLO_bytes_accessed_per_device   / HBM_bw             (819e9)
  collective = collective_bytes_per_device     / ICI_link_bw        (50e9)

XLA's cost analysis counts a scanned while-body ONCE, so the full scanned
compile undercounts by ~n_groups.  We therefore lower the same cell with the
layer loop *unrolled* at G=1 and G=2 groups (same lead/tail/loss/optimizer
"stem"), solve cost(G) = stem + G*body exactly, and extrapolate to the full
depth.  Memory fit comes from the full scanned dry-run record (dryrun.json).

Usage:
  PYTHONPATH=src python -m repro.roofline.analysis \
      --dryrun results/dryrun.json --out results/roofline.json
"""
import argparse
import dataclasses
import json

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_skip_reason
from repro.launch import dryrun as dr
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.roofline.hlo import collective_bytes_from_text
from repro.roofline.hw import V5E


def _reduced_cfg(cfg, n_groups: int):
    """Same lead/tail structure, n_groups repetitions of the pattern."""
    lead = cfg.first_k_dense
    plen = len(cfg.block_pattern)
    full_rest = cfg.n_layers - lead
    tail = full_rest - (full_rest // plen) * plen
    n_layers = lead + n_groups * plen + tail
    enc = cfg.enc_layers
    red = dataclasses.replace(cfg, n_layers=n_layers,
                              enc_layers=min(enc, n_groups * plen) if enc
                              else 0)
    return red


def _measure(cfg, shape_name: str, mesh, rules=None) -> dict:
    # microbatches=1: the gradient-accumulation lax.scan body would also be
    # counted once by cost analysis; the roofline lower must see every op.
    hyper = dataclasses.replace(dr.train_hyper_for(cfg.name),
                                microbatches=1, unroll=True)
    fn, args, in_sh, out_sh, donate = dr.build_cell(cfg, shape_name, mesh,
                                                    rules=rules, unroll=True,
                                                    hyper_override=hyper)
    with jax.set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    colls = collective_bytes_from_text(compiled.as_text())
    return {
        "flops": ca.get("flops", 0.0),
        "bytes": ca.get("bytes accessed", 0.0),
        "coll_bytes": colls["total_bytes"],
        "coll_by_kind": colls["bytes_by_kind"],
    }


def _extrapolate(m1: dict, m2: dict, g_full: int) -> dict:
    out = {}
    for key in ("flops", "bytes", "coll_bytes"):
        body = m2[key] - m1[key]
        stem = m1[key] - body
        out[key] = max(stem + g_full * body, 0.0)
        out[key + "_body"] = body
        out[key + "_stem"] = stem
    kinds = set(m1["coll_by_kind"]) | set(m2["coll_by_kind"])
    out["coll_by_kind"] = {}
    for k in kinds:
        b = m2["coll_by_kind"].get(k, 0.0) - m1["coll_by_kind"].get(k, 0.0)
        s = m1["coll_by_kind"].get(k, 0.0) - b
        out["coll_by_kind"][k] = s + g_full * b
    return out


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens/step."""
    n = transformer.active_param_count(cfg)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch          # decode: 1 token per seq


def roofline_cell(arch: str, shape_name: str, hw=V5E,
                  verbose: bool = True, rules=None, label: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name}
    skip = shape_skip_reason(cfg, shape_name)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec
    mesh = make_production_mesh(multi_pod=False)
    n_chips = mesh.devices.size
    try:
        plen = len(cfg.block_pattern)
        g_full = (cfg.n_layers - cfg.first_k_dense) // plen
        m1 = _measure(_reduced_cfg(cfg, 1), shape_name, mesh, rules)
        m2 = _measure(_reduced_cfg(cfg, 2), shape_name, mesh, rules)
        full = _extrapolate(m1, m2, g_full)

        t_comp = full["flops"] / hw.peak_flops_bf16
        t_mem = full["bytes"] / hw.hbm_bw
        t_coll = full["coll_bytes"] / hw.ici_link_bw
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        mf = model_flops(cfg, shape)
        hlo_total = full["flops"] * n_chips
        rec.update({
            "status": "ok",
            "mesh": "16x16",
            "flops_per_device": full["flops"],
            "bytes_per_device": full["bytes"],
            "coll_bytes_per_device": full["coll_bytes"],
            "coll_by_kind": full["coll_by_kind"],
            "t_compute_s": t_comp,
            "t_memory_s": t_mem,
            "t_collective_s": t_coll,
            "dominant": dominant,
            "model_flops": mf,
            "useful_flops_ratio": mf / hlo_total if hlo_total else 0.0,
            "roofline_bound_s": max(terms.values()),
            "step_lower_bound_s": max(terms.values()),
        })
        if label:
            rec["label"] = label
        if verbose:
            print(f"{arch:28s} {shape_name:12s} comp={t_comp*1e3:8.2f}ms "
                  f"mem={t_mem*1e3:8.2f}ms coll={t_coll*1e3:8.2f}ms "
                  f"dom={dominant:10s} useful={rec['useful_flops_ratio']:.2f}")
    except Exception as e:  # noqa: BLE001
        import traceback
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-1500:]
        if verbose:
            print(f"{arch} x {shape_name}: FAILED {rec['error']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args()
    archs = (args.arch,) if args.arch else ARCH_IDS
    shapes = (args.shape,) if args.shape else tuple(SHAPES)
    records = [roofline_cell(a, s) for a in archs for s in shapes]
    n_ok = sum(r["status"] == "ok" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"roofline: {n_ok} ok, {n_err} failed")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print("wrote", args.out)


if __name__ == "__main__":
    main()
