"""HLO-text parsing: collective-communication bytes + compile-cost envelopes.

`compiled.cost_analysis()` does not report collective traffic, so we parse
the (SPMD-partitioned) HLO text and sum operand bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

Caveat handled by the caller (repro.roofline.analysis): ops inside a `while`
body appear once in the text regardless of trip count; the roofline table is
therefore built from unrolled L=1/L=2 lowers where every op instance is
visible, while dry-run records report the raw per-text totals alongside the
schedule (op kinds + counts).

`cost_envelope(compiled)` bundles the XLA cost/memory analyses plus the
collective-byte parse into one flat dict — the per-compile-group envelope
recorded by `analysis.hlo_budget` and attached to `GroupProfile`.
"""
from __future__ import annotations

import re
import warnings

_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
    # sub-byte int packs
    "s4": 0.5, "u4": 0.5,
    # the FP8 zoo
    "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3b11fnuz": 1,
    "f8e4m3fnuz": 1, "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
}

# e.g. "f32[16,128]{1,0}" or "bf16[8,16,128]"
_TENSOR = re.compile(r"\b(\w+)\[([\d,]*)\]")
# an HLO instruction line: "%name = <result shape(s)> <op>(...)".
# Optimized HLO prints operands as bare %names, so bytes come from the
# RESULT shape(s) between '=' and the op mnemonic.
_INSTR = re.compile(
    r"=\s*(.*?)\s*"
    r"(all-gather-start|all-reduce-start|collective-permute-start|"
    r"all-gather-done|all-reduce-done|collective-permute-done|"
    r"all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"\(")

# dtypes we've already warned about, so a 10^5-line HLO text warns once.
_warned_dtypes: set[str] = set()


def _tensor_bytes(dtype: str, dims: str,
                  unknown: set | None = None) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    width = _DTYPE_BYTES.get(dtype)
    if width is None:
        if unknown is not None:
            unknown.add(dtype)
        if dtype not in _warned_dtypes:
            _warned_dtypes.add(dtype)
            warnings.warn(
                f"hlo: unknown dtype {dtype!r} in collective result shape; "
                f"assuming 4 B/elem — add it to _DTYPE_BYTES",
                stacklevel=2)
        width = 4
    return n * width


def collective_bytes_from_text(txt: str) -> dict:
    """Sum result-tensor bytes per collective kind over the whole HLO text.

    `-done` halves of async pairs are skipped (their `-start` already counted
    the payload).  Dtypes missing from `_DTYPE_BYTES` are assumed 4 B/elem
    and reported under ``"unknown_dtypes"`` so the caller can surface the
    guess instead of silently trusting the total.
    """
    count: dict[str, int] = {k: 0 for k in _KINDS}
    total: dict[str, float] = {k: 0.0 for k in _KINDS}
    unknown: set[str] = set()
    for line in txt.splitlines():
        m = _INSTR.search(line)
        if not m:
            continue
        op = m.group(2)
        if op.endswith("-done"):
            continue
        kind = op.replace("-start", "")
        results = m.group(1)
        b = sum(_tensor_bytes(d, s, unknown)
                for d, s in _TENSOR.findall(results))
        count[kind] += 1
        total[kind] += b
    return {
        "count_by_kind": {k: v for k, v in count.items() if v},
        "bytes_by_kind": {k: round(v, 1) for k, v in total.items() if v},
        "total_bytes": float(sum(total.values())),
        "unknown_dtypes": sorted(unknown),
    }


def cost_envelope(compiled) -> dict:
    """Flop/byte/memory/collective envelope of one compiled executable.

    Keys (all floats except ``unknown_dtypes``): flops, transcendentals,
    bytes_accessed (XLA cost analysis); argument_bytes, output_bytes,
    temp_bytes, peak_bytes (memory analysis; peak = args + outs + temps,
    alias overlap subtracted); collective_bytes + unknown_dtypes (HLO-text
    parse).  Backends that return a per-computation list from
    `cost_analysis()` (CPU) are normalized to the entry-computation dict.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    arg = float(getattr(mem, "argument_size_in_bytes", 0))
    out = float(getattr(mem, "output_size_in_bytes", 0))
    tmp = float(getattr(mem, "temp_size_in_bytes", 0))
    alias = float(getattr(mem, "alias_size_in_bytes", 0))
    coll = collective_bytes_from_text(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "argument_bytes": arg,
        "output_bytes": out,
        "temp_bytes": tmp,
        "peak_bytes": arg + out + tmp - alias,
        "collective_bytes": float(coll["total_bytes"]),
        "unknown_dtypes": coll["unknown_dtypes"],
    }
