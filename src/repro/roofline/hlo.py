"""HLO-text parsing: collective-communication bytes.

`compiled.cost_analysis()` does not report collective traffic, so we parse
the (SPMD-partitioned) HLO text and sum operand bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

Caveat handled by the caller (repro.roofline.analysis): ops inside a `while`
body appear once in the text regardless of trip count; the roofline table is
therefore built from unrolled L=1/L=2 lowers where every op instance is
visible, while dry-run records report the raw per-text totals alongside the
schedule (op kinds + counts).
"""
from __future__ import annotations

import re

_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g. "f32[16,128]{1,0}" or "bf16[8,16,128]"
_TENSOR = re.compile(r"\b(\w+)\[([\d,]*)\]")
# an HLO instruction line: "%name = <result shape(s)> <op>(...)".
# Optimized HLO prints operands as bare %names, so bytes come from the
# RESULT shape(s) between '=' and the op mnemonic.
_INSTR = re.compile(
    r"=\s*(.*?)\s*"
    r"(all-gather-start|all-reduce-start|collective-permute-start|"
    r"all-gather-done|all-reduce-done|collective-permute-done|"
    r"all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"\(")


def _tensor_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_text(txt: str) -> dict:
    """Sum result-tensor bytes per collective kind over the whole HLO text.

    `-done` halves of async pairs are skipped (their `-start` already counted
    the payload).
    """
    count: dict[str, int] = {k: 0 for k in _KINDS}
    total: dict[str, float] = {k: 0.0 for k in _KINDS}
    for line in txt.splitlines():
        m = _INSTR.search(line)
        if not m:
            continue
        op = m.group(2)
        if op.endswith("-done"):
            continue
        kind = op.replace("-start", "")
        results = m.group(1)
        b = sum(_tensor_bytes(d, s) for d, s in _TENSOR.findall(results))
        count[kind] += 1
        total[kind] += b
    return {
        "count_by_kind": {k: v for k, v in count.items() if v},
        "bytes_by_kind": {k: round(v, 1) for k, v in total.items() if v},
        "total_bytes": float(sum(total.values())),
    }
