"""roofline — TPU-v5e roofline terms from compiled dry-run artifacts."""

from repro.roofline.hw import V5E
from repro.roofline.hlo import collective_bytes_from_text

__all__ = ["V5E", "collective_bytes_from_text"]
