"""train — loss/step factories, sharding rules, serving steps."""

from repro.train.sharding import param_pspecs, batch_pspec, ShardingRules
from repro.train.train_step import (
    TrainState,
    TrainHyper,
    init_train_state,
    make_train_step,
    loss_fn,
)
from repro.train.serve_step import make_prefill_step, make_decode_step

__all__ = [
    "param_pspecs", "batch_pspec", "ShardingRules",
    "TrainState", "TrainHyper", "init_train_state", "make_train_step",
    "loss_fn", "make_prefill_step", "make_decode_step",
]
