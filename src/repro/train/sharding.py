"""Sharding rules: parameter-path -> PartitionSpec over the production mesh.

Mesh axes: ("pod",) "data", "model".  The batch shards over (pod, data);
tensor/expert parallelism over "model".  Rules are name+parent based with
shape-aware fallbacks: e.g. attention projections shard the head axis when
head-count divides the model axis, else the head_dim axis, else the model
dim, else replicate (qwen3 kv=8 and llama4 H=40 don't divide 16; internvl's
vocab 151655 is odd, so its embedding shards d_model instead).

An optional FSDP mode additionally shards the big matrices over "data"
(ZeRO-3-style; a hillclimb lever, not the baseline).
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    fsdp: bool = False
    data_axes: tuple = ("pod", "data")
    tensor_parallel: bool = True      # False: pure FSDP — the "model" axis
                                      # joins data_axes and no weight axis
                                      # is model-sharded (hillclimb C1')
    decode_cache_seq_shard: bool = False  # shard KV caches on the sequence
                                          # axis over "model" (hillclimb B2)


def _div(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


def _pick(shape, prefs, msize):
    """Assign "model" to the first preferred axis whose dim divides msize."""
    out = [None] * len(shape)
    if msize <= 1:                       # tensor parallelism disabled
        return out
    for ax in prefs:
        if ax < len(shape) and _div(shape[ax], msize):
            out[ax] = "model"
            return out
    return out


def _with_fsdp(spec, shape, axis, dsize, enabled):
    if enabled and spec[axis] is None and _div(shape[axis], dsize):
        spec = list(spec)
        spec[axis] = "data"
    return spec


def _spec_for(parent: str, name: str, shape, rules: ShardingRules,
              msize: int, dsize: int):
    nd = len(shape)
    f = rules.fsdp

    if name == "embed":                       # [V, D]
        return _pick(shape, (0, 1), msize)
    if name == "head":                        # [D, V]
        return _with_fsdp(_pick(shape, (1, 0), msize), shape, 0, dsize, f)
    if name == "proj_vision":
        return [None, None]

    if parent in ("attn", "self_attn", "cross_attn"):
        if name == "wq" or name == "wk" or name == "wv":   # [D, H, dh]
            return _with_fsdp(_pick(shape, (1, 2), msize), shape, 0, dsize, f)
        if name == "wo":                       # [H, dh, D]
            return _with_fsdp(_pick(shape, (0, 1), msize), shape, 2, dsize, f)
        if name in ("bq", "bk", "bv"):         # [H, dh]
            return _pick(shape, (0, 1), msize)
        return [None] * nd                     # q_norm / k_norm

    if parent in ("ffn", "shared"):
        if name in ("gate", "up"):             # [D, F]
            return _with_fsdp(_pick(shape, (1,), msize), shape, 0, dsize, f)
        if name == "down":                     # [F, D]
            return _with_fsdp(_pick(shape, (0,), msize), shape, 1, dsize, f)

    if parent == "moe":
        if name == "router":
            return [None, None]
        if name in ("w_gate", "w_up", "w_down"):   # [E, D, F] / [E, F, D]
            spec = _pick(shape, (0,), msize)       # expert parallel
            return _with_fsdp(spec, shape, 1, dsize, f)

    if parent == "rec":
        if name in ("w_lin", "w_x", "w_a", "w_i"):     # [D, Dr]
            return _with_fsdp(_pick(shape, (1,), msize), shape, 0, dsize, f)
        if name == "conv_w":                   # [W, Dr]
            return _pick(shape, (1,), msize)
        if name == "w_out":                    # [Dr, D]
            return _with_fsdp(_pick(shape, (0,), msize), shape, 1, dsize, f)
        if name in ("conv_b", "lam"):          # [Dr]
            return _pick(shape, (0,), msize)

    if parent == "mlstm":
        if name in ("w_up", "w_gate", "conv_w"):       # [D, Di] / [W, Di]
            return _with_fsdp(_pick(shape, (1,), msize), shape, 0, dsize, f)
        if name in ("wq", "wk", "wv", "w_if"):         # [Di, H, x]
            return _pick(shape, (0, 2), msize)
        if name == "b_if":
            return [None] * nd
        if name in ("conv_b", "skip", "out_norm"):     # [Di]
            return _pick(shape, (0,), msize)
        if name == "w_down":                   # [Di, D]
            return _with_fsdp(_pick(shape, (0,), msize), shape, 1, dsize, f)

    if parent == "slstm":
        if name == "w_gates":                  # [D, H, 4, dh]
            return _pick(shape, (1, 3), msize)
        if name == "r_gates":                  # [H, 4, dh, dh]
            return _pick(shape, (0, 3), msize)
        if name == "b_gates":                  # [H, 4, dh]
            return _pick(shape, (0, 2), msize)
        if name in ("ff_gate", "ff_up"):
            return _with_fsdp(_pick(shape, (1,), msize), shape, 0, dsize, f)
        if name == "ff_down":
            return _with_fsdp(_pick(shape, (0,), msize), shape, 1, dsize, f)
        return [None] * nd                     # conv/out_norm on d_model

    return [None] * nd                         # norms, scalars


def _path_names(path) -> list[str]:
    out = []
    for e in path:
        if hasattr(e, "key"):
            out.append(str(e.key))
        elif hasattr(e, "name"):
            out.append(str(e.name))
        else:
            out.append(str(e))
    return out


_STACKED = ("groups", "enc", "dec")


def param_pspecs(cfg: ModelConfig, params_shape, mesh,
                 rules: ShardingRules = None):
    """PartitionSpec pytree matching ``params_shape`` (from eval_shape)."""
    rules = rules or ShardingRules(
        data_axes=tuple(a for a in mesh.axis_names if a != "model"))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = sizes.get("model", 1) if rules.tensor_parallel else 1
    dsize = 1
    for a in rules.data_axes:
        dsize *= sizes.get(a, 1)

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1]
        parent = names[-2] if len(names) >= 2 else ""
        stacked = any(n in _STACKED for n in names)
        shape = leaf.shape[1:] if stacked else leaf.shape
        spec = _spec_for(parent, name, shape, rules, msize, dsize)
        # FSDP "data" means all data axes; expand tuple axes
        spec = [rules.data_axes if s == "data" else s for s in spec]
        if stacked:
            spec = [None] + spec
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_pspec(rules: ShardingRules = None) -> P:
    rules = rules or ShardingRules()
    return P(rules.data_axes)


def auto_pspec(shape, mesh, rules: ShardingRules = None,
               stacked: bool = False) -> P:
    """Heuristic spec for activation-like arrays (caches, batches): shard the
    first divisible axis over the data axes and the next divisible axis over
    "model". Falls back to replication per-axis."""
    rules = rules or ShardingRules(
        data_axes=tuple(a for a in mesh.axis_names if a != "model"))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dsize = 1
    for a in rules.data_axes:
        dsize *= sizes.get(a, 1)
    msize = sizes.get("model", 1)
    spec = [None] * len(shape)
    start = 1 if stacked else 0
    # batch-like axis -> data
    for i in range(start, len(shape)):
        if _div(shape[i], dsize):
            spec[i] = rules.data_axes
            start = i + 1
            break
    # model axis: prefer trailing dims (head_dim / kv heads), never the
    # huge sequence axis of a KV cache
    for i in reversed(range(start, len(shape))):
        if spec[i] is None and _div(shape[i], msize) and shape[i] >= msize:
            spec[i] = "model"
            break
    return P(*spec)
