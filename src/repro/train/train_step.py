"""Training step factory: loss, grads, AdamW, optional microbatching and
gradient compression — pure functions ready for `jax.jit(in_shardings=...)`
under the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.config import ModelConfig
from repro.optim import (
    AdamWConfig,
    CompressionConfig,
    adamw_init,
    adamw_update,
    compress_gradients,
    cosine_schedule,
    init_error_feedback,
)

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    opt: AdamWConfig = AdamWConfig()
    warmup: int = 100
    total_steps: int = 10_000
    aux_weight: float = 0.01           # MoE load-balance loss weight
    microbatches: int = 1              # gradient accumulation
    compression: CompressionConfig = CompressionConfig()
    use_kernel: bool = False
    remat: bool = True
    unroll: bool = False               # python-loop layers (roofline lowers)
    param_dtype: str = "float32"       # "bfloat16" = mixed-precision training


class TrainState(NamedTuple):
    params: Any
    opt: Any
    residual: Any                      # error feedback (None if no compression)
    step: Array


def init_train_state(cfg: ModelConfig, hyper: TrainHyper, key) -> TrainState:
    params = api.init_params(cfg, key)
    dt = jnp.dtype(hyper.param_dtype)
    params = jax.tree.map(lambda p: p.astype(dt), params)
    resid = (init_error_feedback(params)
             if hyper.compression.scheme != "none" else None)
    return TrainState(params=params, opt=adamw_init(hyper.opt, params),
                      residual=resid, step=jnp.zeros((), jnp.int32))


def loss_fn(cfg: ModelConfig, params, batch: dict, hyper: TrainHyper
            ) -> tuple[Array, dict]:
    logits, aux = api.forward(cfg, params, batch,
                              use_kernel=hyper.use_kernel, remat=hyper.remat,
                              unroll=hyper.unroll)
    tokens = batch["tokens"]
    # multimodal prefixes (vision tokens) are not scored
    prefix = logits.shape[1] - tokens.shape[1]
    logits = logits[:, prefix:]
    targets = tokens[:, 1:]
    pred = logits[:, :-1]
    logp = jax.nn.log_softmax(pred.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    ce = nll.mean()
    loss = ce + hyper.aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, hyper: TrainHyper):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, hyper), has_aux=True)(params)

    def train_step(state: TrainState, batch: dict):
        if hyper.microbatches > 1:
            # batch arrives pre-split: leaves [mb, gb/mb, ...] so the global
            # batch axis stays cleanly sharded over the data mesh axes.
            mb = hyper.microbatches
            split = batch
            assert all(x.shape[0] == mb for x in jax.tree.leaves(batch)), \
                f"microbatched train_step expects leading dim {mb}"

            def acc_fn(carry, mb_batch):
                (loss, metrics), grads = grads_of(state.params, mb_batch)
                gsum, lsum = carry
                gsum = jax.tree.map(jnp.add, gsum, grads)
                return (gsum, lsum + loss), metrics

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                state.params)
            (gsum, lsum), metrics = jax.lax.scan(acc_fn, (zero, 0.0), split)
            grads = jax.tree.map(lambda g: g / mb, gsum)
            loss = lsum / mb
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grads_of(state.params, batch)

        residual = state.residual
        if hyper.compression.scheme != "none":
            grads, residual = compress_gradients(hyper.compression, grads,
                                                 residual)

        lr_scale = cosine_schedule(state.step, hyper.warmup, hyper.total_steps)
        params, opt, opt_metrics = adamw_update(hyper.opt, state.opt,
                                                state.params, grads, lr_scale)
        new_state = TrainState(params=params, opt=opt, residual=residual,
                               step=state.step + 1)
        return new_state, {"loss": loss, **metrics, **opt_metrics,
                           "lr_scale": lr_scale}

    return train_step
