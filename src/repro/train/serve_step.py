"""Serving steps: prefill (prompt -> cache) and decode (one token/step)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.config import ModelConfig

Array = jnp.ndarray


def make_prefill_step(cfg: ModelConfig, max_len: int, use_kernel: bool = False,
                      unroll: bool = False):
    def prefill_step(params, batch: dict):
        logits, cache = api.prefill(cfg, params, batch, max_len,
                                    use_kernel=use_kernel, unroll=unroll)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, greedy: bool = True,
                     unroll: bool = False):
    def decode_step(params, cache: dict, token: Array, index: Array):
        logits, cache = api.decode_step(cfg, params, cache, token, index,
                                        unroll=unroll)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache
    return decode_step


def generate(cfg: ModelConfig, params, batch: dict, max_new: int,
             max_len: int) -> Array:
    """Greedy generation loop (used by examples/serve.py)."""
    tok, cache = make_prefill_step(cfg, max_len)(params, batch)
    start = batch["tokens"].shape[1]
    step = make_decode_step(cfg)
    out = [tok]

    def body(carry, i):
        tok, cache = carry
        tok, cache = step(params, cache, tok, start + i)
        return (tok, cache), tok

    (_, _), toks = jax.lax.scan(body, (tok, cache), jnp.arange(max_new - 1))
    return jnp.concatenate([out[0][None], toks], axis=0).T  # [B, max_new]
