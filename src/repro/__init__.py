"""repro — production-grade JAX reproduction of MLTCP (Rajasekaran et al., 2024).

Layers:
  core/       MLTCP protocol: aggressiveness functions, favoritism, Algorithm 1,
              congestion-control variants (Reno / CUBIC / DCQCN) +/- MLTCP.
  netsim/     vectorized fluid network simulator (links, queues, RED/ECN, RTT).
  workload/   DNN-job communication/compute phase models + baselines.
  models/     the 10 assigned architectures as composable JAX modules.
  configs/    exact public configs + input shapes.
  kernels/    Pallas TPU kernels (flash attention, fused CC tick, RG-LRU scan).
  data/optim/train/checkpoint/launch/cluster/roofline — training substrate.
"""

__version__ = "1.0.0"
