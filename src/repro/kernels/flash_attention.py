"""Flash attention (forward) as a Pallas TPU kernel.

Tiling: grid = (batch*q_heads, T/BLOCK_Q, S/BLOCK_K); the innermost grid
dimension is sequential ("arbitrary") so VMEM scratch (running max m,
normalizer l, f32 accumulator) persists across K/V blocks — the online
softmax never materializes the [T, S] matrix.  GQA is handled in the
BlockSpec index maps (query head -> kv head = h // group), so KV heads are
never repeated in memory.  Causal + sliding-window masks and the Gemma-2
logit softcap are applied in-kernel.

MXU alignment: BLOCK_Q/BLOCK_K default 512 with head_dim padded to a
multiple of 128 by the wrapper (ops.py).  Validated on CPU in interpret
mode against ref.py; the backward pass recomputes through the jnp oracle
(custom_vjp in ops.py), the standard recompute strategy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30

# JAX renamed TPUCompilerParams -> CompilerParams across releases; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  softcap: float | None, block_q: int, block_k: int,
                  n_k: int, s_real: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # [bq, dh]
    k = k_ref[0].astype(jnp.float32)                    # [bk, dh]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = kpos < s_real          # padded keys never attended
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                 # [bq, 1]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == n_k - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float | None = None, s_real: int = 0,
                        scale: float | None = None,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = True):
    """q: [B, T, H, D]; k/v: [B, S, K, D] -> [B, T, H, D].

    Requires T % block_q == 0, S % block_k == 0 and D % 128 == 0 (the ops.py
    wrapper pads); GQA group = H // K resolved in the index maps. ``s_real``
    masks padded key positions (0 = all real).
    """
    b, t, h, dh = q.shape
    s_len, kh = k.shape[1], k.shape[2]
    g = h // kh
    block_q = min(block_q, t)
    block_k = min(block_k, s_len)
    assert t % block_q == 0 and s_len % block_k == 0, (t, s_len)
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    n_q, n_k = t // block_q, s_len // block_k

    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, t, dh)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * kh, s_len, dh)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * kh, s_len, dh)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, n_k=n_k,
        s_real=s_real or s_len)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, iq, ik: (bh // g, ik, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, iq, ik: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return jnp.moveaxis(out.reshape(b, h, t, dh), 1, 2)
