"""Fused MLTCP congestion-control tick as a Pallas TPU kernel.

One kernel invocation advances *all* flows one simulator tick: Algorithm 1
(iteration-boundary detection + bytes_ratio), the bandwidth-aggressiveness
function F, and the selected congestion-control update (Reno / CUBIC /
DCQCN, WI/MD variants) — 17 state arrays updated in a single VMEM-resident
pass.  This is the netsim hot loop when simulating cluster-scale fabrics
(10^4-10^5 flows x 10^6+ ticks): the unfused jnp path round-trips ~20
arrays through HBM per tick, while the fused kernel reads each once.

Flow state is reshaped to [rows, 128] lanes (TPU vector width); every op is
elementwise, so blocks tile (8, 128) and the grid parallelizes over rows.
Algorithm and MLTCP variant are *static* (one fabric runs one CC), so the
kernel specializes at trace time with zero runtime branching — but the
protocol *scalars* (DYN_FIELDS: F's slope/intercept, Algorithm 1's
g/gamma/INIT_COMM_GAP) arrive as an f32[NDYN] SMEM operand, and the
Static-baseline per-flow factors as an optional [R, 128] lanes operand, so
traced sweep values (`simulate_sweep`'s vmapped K axis) keep the kernel
fused instead of forcing a retrace or an oracle fallback (DESIGN.md §4).
The SMEM ref is a plain operand rather than a `PrefetchScalarGridSpec`
scalar-prefetch argument deliberately: the pallas batching rule lowers a
*batched* prefetch operand to a serial `lax.scan` over the batch, which
would run a K-point sweep one simulation at a time.

Oracle: repro.core.cc_tick (via ref.py) — the exact module the netsim
engine uses — fuzz-tested field-by-field (including under traced
DynamicParams and vmapped sweeps) in tests/test_kernels.py.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import iteration
from repro.core.cc.types import Algo, Variant

LANES = 128
SUBLANES = 8

DET_FIELDS = ("bytes_sent", "prev_ack_tstamp", "iter_gap", "max_gap")
CC_FIELDS = ("cwnd", "ssthresh", "cooldown", "w_max", "epoch_start",
             "rate_cur", "rate_target", "alpha", "t_last_cnp", "t_last_inc",
             "t_last_alpha")
IN_ORDER = (list(DET_FIELDS) + list(CC_FIELDS)
            + ["stage", "prev_ratio", "num_acks", "ack_bytes", "loss", "cnp",
               "now", "total_bytes", "job_numer"])
OUT_ORDER = list(DET_FIELDS) + list(CC_FIELDS) + ["stage", "ratio", "rate"]

# Layout of the dyn SMEM operand (== core.DynamicParams field order).
DYN_FIELDS = ("slope", "intercept", "g", "gamma", "init_comm_gap")
NDYN = len(DYN_FIELDS)

# The kernel body's name in traced programs (`name_and_src_info`); the
# pallas batching rule appends "_batched" under vmap, so locate the
# CC-tick pallas_call by prefix-matching this.  This is the static
# analyzer's handle onto the body jaxpr (analysis/kernel_lint.py): the
# body is reachable from the already-traced sweep jaxpr via the
# pallas_call eqn's `jaxpr` param, so linting it costs zero extra traces.
KERNEL_NAME = "_kernel"


@dataclasses.dataclass(frozen=True)
class KernelLayout:
    """The operand/grid layout a (rows, factors) specialization must lower
    to — the contract between `mltcp_tick_arrays` (which builds the
    pallas_call) and `analysis.kernel_lint` (which proves the traced
    program matches).  Everything here is static: if ops.py's packing and
    this expectation ever diverge, the kernel lint fires on the next run.
    """

    rows: int                       # [rows, 128]-packed flow state
    block: tuple                    # (min(SUBLANES, rows), LANES)
    grid: tuple                     # (rows // block[0],) — exact cover
    n_inputs: int                   # dyn + optional factors + IN_ORDER
    n_outputs: int                  # OUT_ORDER
    dyn_index: int                  # position of the SMEM scalars operand
    dyn_shape: tuple                # (NDYN,)
    use_static_factors: bool


def expected_layout(rows: int, use_static_factors: bool = False
                    ) -> KernelLayout:
    """The layout `mltcp_tick_arrays` produces for `rows` packed rows."""
    block = (min(SUBLANES, rows), LANES)
    return KernelLayout(
        rows=rows, block=block, grid=(rows // block[0],),
        n_inputs=1 + int(use_static_factors) + len(IN_ORDER),
        n_outputs=len(OUT_ORDER),
        dyn_index=0, dyn_shape=(NDYN,),
        use_static_factors=use_static_factors)


def _kernel(p, dyn_ref, *refs):
    # protocol scalars, read from SMEM (operand-carried — possibly traced
    # sweep values; see module docstring)
    slope, intercept = dyn_ref[0], dyn_ref[1]
    g, gamma, init_comm_gap = dyn_ref[2], dyn_ref[3], dyn_ref[4]
    if p["use_static_factors"]:
        factors_r, refs = refs[0], refs[1:]
    n_in = len(IN_ORDER)
    (bytes_sent_r, prev_ack_r, iter_gap_r, max_gap_r,
     cwnd_r, ssthresh_r, cooldown_r, w_max_r, epoch_r,
     rate_cur_r, rate_tgt_r, alpha_r, t_cnp_r, t_inc_r, t_alpha_r,
     stage_r, prev_ratio_r, acks_r, ackb_r, loss_r, cnp_r, now_r, tb_r,
     jobnum_r) = refs[:n_in]
    (o_bytes_sent, o_prev_ack, o_iter_gap, o_max_gap,
     o_cwnd, o_ssthresh, o_cooldown, o_w_max, o_epoch,
     o_rate_cur, o_rate_tgt, o_alpha, o_t_cnp, o_t_inc, o_t_alpha,
     o_stage, o_ratio, o_rate) = refs[n_in:]

    now = now_r[...]
    acks = acks_r[...]
    has_ack = acks > 0.0

    # ---------------- Algorithm 1 (core.iteration semantics) --------------
    # acked bytes arrive pre-multiplied (iteration.ack_bytes operand) so the
    # product's rounding is pinned outside the kernel (bit-stable vs oracle)
    bytes_sent = bytes_sent_r[...] + ackb_r[...]
    curr_gap = now - prev_ack_r[...]
    max_gap = jnp.maximum(max_gap_r[...], curr_gap)
    new_iter = curr_gap > g * iter_gap_r[...]
    iter_gap_upd = (1.0 - gamma) * iter_gap_r[...] + gamma * max_gap
    numer = jobnum_r[...] if p["aggregate"] else bytes_sent
    ratio_mid = iteration.byte_ratio(numer, tb_r[...])

    boundary = has_ack & new_iter
    o_bytes_sent[...] = jnp.where(boundary, 0.0,
                                  jnp.where(has_ack, bytes_sent,
                                            bytes_sent_r[...]))
    ratio = jnp.where(boundary, 0.0,
                      jnp.where(has_ack, ratio_mid, prev_ratio_r[...]))
    o_ratio[...] = ratio
    o_prev_ack[...] = jnp.where(has_ack, now, prev_ack_r[...])
    o_iter_gap[...] = jnp.where(boundary, iter_gap_upd, iter_gap_r[...])
    o_max_gap[...] = jnp.where(boundary,
                               jnp.broadcast_to(init_comm_gap, max_gap.shape),
                               jnp.where(has_ack, max_gap, max_gap_r[...]))

    # ---------------- F(bytes_ratio), variant routing ----------------
    if p["variant"] == int(Variant.OFF):
        adaptive = jnp.ones_like(ratio)
    else:
        adaptive = slope * ratio + intercept
    if p["use_static_factors"]:
        # Static [67] with the adaptive sentinel (mirrors core.cc_tick):
        # factor >= 0 replaces F for that flow, factor < 0 keeps the
        # computed F — an exact elementwise select, so mixed Static /
        # adaptive sweep points share this one fused program
        f_vals = jnp.where(factors_r[...] >= 0.0, factors_r[...], adaptive)
    else:
        f_vals = adaptive
    one = jnp.ones_like(f_vals)
    f_wi = f_vals if p["variant"] in (int(Variant.WI), int(Variant.BOTH)) \
        else one
    f_md = f_vals if p["variant"] in (int(Variant.MD), int(Variant.BOTH)) \
        else one

    loss = loss_r[...] > 0.0
    cnp_sig = cnp_r[...] > 0.0
    algo = p["algo"]

    if algo in (int(Algo.RENO), int(Algo.CUBIC)):
        cwnd = cwnd_r[...]
        in_ss = cwnd < ssthresh_r[...]
        if algo == int(Algo.RENO):
            grow_ca = f_wi * acks / jnp.maximum(cwnd, 1e-6)       # Eq. 5
            beta = p["reno_beta"]
        else:
            c = p["cubic_c"] * p["cubic_scale"]
            tt = jnp.maximum(now - epoch_r[...], 0.0)
            # (1-beta)/c is a python-float constant, as in core.cc.cubic
            kk = jnp.cbrt(w_max_r[...] * ((1.0 - p["cubic_beta"]) / c))
            target = c * (f_wi * tt - kk) ** 3 + w_max_r[...]     # Eq. 9
            grow = acks * jnp.maximum(target - cwnd, 0.0) \
                / jnp.maximum(cwnd, 1e-6)
            grow_ca = jnp.minimum(grow, 0.5 * cwnd + 1.0)
            beta = p["cubic_beta"]
        cwnd_inc = cwnd + jnp.where(in_ss, acks, grow_ca)
        do_cut = loss & (cooldown_r[...] <= 0.0)
        cwnd_cut = jnp.maximum(jnp.minimum(f_md * beta, 1.0) * cwnd,  # Eq. 7/11
                               p["min_cwnd"])
        o_cwnd[...] = jnp.where(do_cut, cwnd_cut, cwnd_inc)
        o_ssthresh[...] = jnp.where(do_cut, jnp.maximum(cwnd_cut, 2.0),
                                    ssthresh_r[...])
        o_cooldown[...] = jnp.where(
            do_cut, p["rtt"],
            jnp.maximum(cooldown_r[...] - p["tick_dt"], 0.0))
        if algo == int(Algo.CUBIC):
            o_w_max[...] = jnp.where(do_cut, cwnd, w_max_r[...])
            o_epoch[...] = jnp.where(do_cut, now, epoch_r[...])
        else:
            o_w_max[...] = w_max_r[...]
            o_epoch[...] = epoch_r[...]
        o_rate_cur[...] = rate_cur_r[...]
        o_rate_tgt[...] = rate_tgt_r[...]
        o_alpha[...] = alpha_r[...]
        o_t_cnp[...] = t_cnp_r[...]
        o_t_inc[...] = t_inc_r[...]
        o_t_alpha[...] = t_alpha_r[...]
        o_stage[...] = stage_r[...]
        o_rate[...] = o_cwnd[...] * (p["mss"] / p["rtt"])  # == core send_rate
    else:  # ---------------- DCQCN ----------------
        cnp = cnp_sig & ((now - t_cnp_r[...]) >= p["cnp_interval"])
        alpha_on_cnp = (1.0 - p["dcqcn_g"]) * alpha_r[...] + p["dcqcn_g"]
        md_mult = jnp.minimum(f_md * (1.0 - alpha_r[...] / 2.0), 1.0)  # Eq. 15
        rate_cut = jnp.clip(md_mult * rate_cur_r[...], p["rate_min"],
                            p["line_rate"])
        alpha_fired = (now - t_alpha_r[...]) >= p["alpha_timer"]
        alpha_dec = jnp.where(alpha_fired,
                              (1.0 - p["dcqcn_g"]) * alpha_r[...],
                              alpha_r[...])
        inc_fired = (now - t_inc_r[...]) >= p["inc_timer"]
        stage = stage_r[...] + inc_fired.astype(jnp.int32)
        in_ai = stage > p["fast_recovery_stages"]
        tgt_inc = jnp.where(inc_fired & in_ai,
                            rate_tgt_r[...] + f_wi * p["rate_ai"],  # Eq. 13
                            rate_tgt_r[...])
        tgt_inc = jnp.minimum(tgt_inc, p["line_rate"])
        step_up = jnp.minimum(f_wi, 2.0) * 0.5 * (tgt_inc - rate_cur_r[...])
        rate_inc = jnp.where(inc_fired, rate_cur_r[...] + step_up,
                             rate_cur_r[...])
        o_rate_cur[...] = jnp.clip(jnp.where(cnp, rate_cut, rate_inc),
                                   p["rate_min"], p["line_rate"])
        o_rate_tgt[...] = jnp.clip(jnp.where(cnp, rate_cur_r[...], tgt_inc),
                                   p["rate_min"], p["line_rate"])
        o_alpha[...] = jnp.clip(jnp.where(cnp, alpha_on_cnp, alpha_dec),
                                0.0, 1.0)
        o_stage[...] = jnp.where(cnp, jnp.zeros_like(stage), stage)
        o_t_cnp[...] = jnp.where(cnp, now, t_cnp_r[...])
        o_t_inc[...] = jnp.where(cnp | inc_fired, now, t_inc_r[...])
        o_t_alpha[...] = jnp.where(cnp | alpha_fired, now, t_alpha_r[...])
        o_cwnd[...] = cwnd_r[...]
        o_ssthresh[...] = ssthresh_r[...]
        o_cooldown[...] = cooldown_r[...]
        o_w_max[...] = w_max_r[...]
        o_epoch[...] = epoch_r[...]
        o_rate[...] = o_rate_cur[...]


def mltcp_tick_arrays(cfg_static: dict, dyn: jnp.ndarray, arrays: dict, *,
                      static_factors: jnp.ndarray | None = None,
                      interpret: bool = True) -> dict:
    """Run the fused tick.

    ``dyn``: f32[NDYN] protocol scalars per DYN_FIELDS, carried as an SMEM
    operand (values may be traced — a sweep point — without retracing the
    kernel).  ``arrays``: {field: [R, 128]} per IN_ORDER ("stage" int32,
    rest f32); ``static_factors``: optional [R, 128] per-flow Static [67]
    factors (their *presence* is static, the values are an operand).
    Returns {field: [R, 128]} per OUT_ORDER.
    """
    r = arrays["cwnd"].shape[0]
    block = (min(SUBLANES, r), LANES)
    spec = pl.BlockSpec(block, lambda i: (i, 0))
    ins = [jnp.asarray(dyn, jnp.float32)]
    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM)]
    if static_factors is not None:
        ins.append(static_factors)
        in_specs.append(spec)
    ins += [arrays[k] for k in IN_ORDER]
    in_specs += [spec] * len(IN_ORDER)
    out_shapes = [jax.ShapeDtypeStruct((r, LANES),
                                       jnp.int32 if f == "stage"
                                       else jnp.float32)
                  for f in OUT_ORDER]
    p = dict(cfg_static, use_static_factors=static_factors is not None)
    outs = pl.pallas_call(
        functools.partial(_kernel, p),
        grid=(r // block[0],),
        in_specs=in_specs,
        out_specs=[spec] * len(OUT_ORDER),
        out_shape=out_shapes,
        interpret=interpret,
    )(*ins)
    return dict(zip(OUT_ORDER, outs))
