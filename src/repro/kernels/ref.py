"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

  ref_attention  <-> flash_attention.flash_attention_fwd
  ref_rg_lru     <-> rg_lru.rg_lru_scan
  ref_cc_tick    <-> mltcp_step (== repro.core.cc_tick, the engine's own
                     update — the kernel must match the protocol exactly)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mltcp import cc_tick as ref_cc_tick  # noqa: F401

Array = jnp.ndarray


def ref_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                  window: int = 0, softcap: float | None = None) -> Array:
    """Dense GQA attention. q: [B,T,H,D]; k/v: [B,S,K,D]."""
    b, t, h, dh = q.shape
    s = k.shape[1]
    g = h // k.shape[2]
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bthd,bshd->bths", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(dh)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = jnp.arange(t)
    kpos = jnp.arange(s)
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window and window > 0:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    scores = jnp.where(mask[None, :, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bths,bshd->bthd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_rg_lru(a: Array, b: Array, h0: Array | None = None) -> Array:
    """h_t = a_t * h_{t-1} + b_t via associative scan. a/b: [B,T,D]."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
