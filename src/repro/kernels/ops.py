"""Jit-ready wrappers around the Pallas kernels.

  flash_attention  — pads to block/lane multiples, custom_vjp whose backward
                     recomputes through the jnp oracle (standard recompute);
  rg_lru           — same pattern for the linear-recurrence scan;
  mltcp_cc_tick    — drop-in replacement for repro.core.cc_tick: packs the
                     protocol state into [R, 128] lanes and the protocol
                     scalars (slope/intercept/g/gamma/INIT_COMM_GAP, plus
                     the Static-baseline per-flow factors) into kernel
                     *operands*, runs the fused tick kernel, unpacks.
                     Traced sweep values therefore stay fused; only the
                     structural options the kernel does not implement
                     (non-default favoritism policy, non-linear F family)
                     fall back to the jnp oracle — loudly, via
                     ``FALLBACK_COUNT`` and a one-time warning.

``INTERPRET`` defaults to the ``REPRO_INTERPRET`` env var (default "1"):
this container is CPU-only, and interpret mode executes the kernel body
exactly as the TPU grid would (the brief's validation mode).  On real TPUs
run with ``REPRO_INTERPRET=0`` — or pass ``interpret=False`` per call; every
wrapper takes an ``interpret`` override (None = module default).
"""
from __future__ import annotations

import functools
import os
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import iteration
from repro.core import mltcp as core
from repro.kernels import flash_attention as fa
from repro.kernels import mltcp_step as ms
from repro.kernels import ref
from repro.kernels import rg_lru as rl

Array = jnp.ndarray


def _env_flag(name: str, default: bool) -> bool:
    """Parse a boolean env var ("0"/"false"/"no"/"off" false, anything else
    true); unset *or empty* means the default (a blank export is how shells
    and CI yamls "clear" a variable, not a request for False)."""
    raw = os.environ.get(name)
    if raw is None or not raw.strip():
        return default
    return raw.strip().lower() not in ("0", "false", "no", "off")


# CPU containers interpret; real TPUs run compiled (REPRO_INTERPRET=0).
INTERPRET = _env_flag("REPRO_INTERPRET", True)

# Incremented once per trace that routes mltcp_cc_tick through the jnp
# oracle instead of the fused kernel (mirrors engine.TRACE_COUNT); tests pin
# "a kernel-enabled sweep falls back zero times" on this counter.
FALLBACK_COUNT = 0
_FALLBACK_WARNED: set = set()


def reset_fallback_warnings() -> None:
    """Re-arm the once-per-reason fallback warning.

    The guard is process-global, which is right within one plan (a K-point
    sweep traces the same reason once) but wrong across plans: a later
    `run_plan` that newly falls back would bump FALLBACK_COUNT without the
    named-reason warning.  `run_plan` calls this at entry so each plan
    warns at most once per reason.
    """
    _FALLBACK_WARNED.clear()


def _resolve_interpret(override: Optional[bool]) -> bool:
    return INTERPRET if override is None else override


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q: Array, k: Array, v: Array, causal: bool = True,
                    window: int = 0, softcap: Optional[float] = None,
                    interpret: Optional[bool] = None) -> Array:
    return _flash_fwd_impl(q, k, v, causal, window, softcap, interpret)


def _flash_fwd_impl(q, k, v, causal, window, softcap, interpret=None):
    t, s = q.shape[1], k.shape[1]
    bq = min(fa.DEFAULT_BLOCK_Q, 1 << max((t - 1).bit_length(), 7))
    bk = min(fa.DEFAULT_BLOCK_K, 1 << max((s - 1).bit_length(), 7))
    qp, _ = _pad_to(q, 1, bq)
    kp, _ = _pad_to(k, 1, bk)
    vp, _ = _pad_to(v, 1, bk)
    qp, pad_d = _pad_to(qp, 3, 128)
    kp, _ = _pad_to(kp, 3, 128)
    vp, _ = _pad_to(vp, 3, 128)
    out = fa.flash_attention_fwd(
        qp, kp, vp, causal=causal, window=window, softcap=softcap,
        s_real=s, scale=1.0 / (q.shape[3] ** 0.5),
        block_q=bq, block_k=bk, interpret=_resolve_interpret(interpret))
    if pad_d:
        out = out[..., : q.shape[3]]
    if out.shape[1] != t:
        out = out[:, :t]
    return out


def _flash_vjp_fwd(q, k, v, causal, window, softcap, interpret):
    return _flash_fwd_impl(q, k, v, causal, window, softcap,
                           interpret), (q, k, v)


def _flash_vjp_bwd(causal, window, softcap, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.ref_attention(
        q_, k_, v_, causal=causal, window=window, softcap=softcap), q, k, v)
    return vjp(g)


flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rg_lru(a: Array, b: Array, interpret: Optional[bool] = None) -> Array:
    return _rg_lru_impl(a, b, interpret)


def _rg_lru_impl(a, b, interpret=None):
    ap, pad = _pad_to(a, 2, rl.BLOCK_D)
    bp, _ = _pad_to(b, 2, rl.BLOCK_D)
    out = rl.rg_lru_scan(ap, bp, interpret=_resolve_interpret(interpret))
    return out[..., : a.shape[2]] if pad else out


def _rg_lru_vjp_fwd(a, b, interpret):
    return _rg_lru_impl(a, b, interpret), (a, b)


def _rg_lru_vjp_bwd(interpret, res, g):
    a, b = res
    _, vjp = jax.vjp(ref.ref_rg_lru, a, b)
    return vjp(g)


rg_lru.defvjp(_rg_lru_vjp_fwd, _rg_lru_vjp_bwd)


# ---------------------------------------------------------------------------
# fused protocol tick
# ---------------------------------------------------------------------------

_ROW = ms.LANES * ms.SUBLANES


def packed_rows(n_flows: int) -> int:
    """[rows, 128] rows `mltcp_cc_tick` packs ``n_flows`` flow-state
    vectors into (flows pad to a SUBLANESxLANES multiple, so rows is
    always a multiple of SUBLANES and the grid divides evenly)."""
    return (-(-n_flows // _ROW) * _ROW) // ms.LANES


def kernel_layout(n_flows: int, use_static_factors: bool = False
                  ) -> ms.KernelLayout:
    """The specialization expectation for an ``n_flows``-flow fabric.

    This is the packing contract `analysis.kernel_lint` checks the traced
    pallas_call against — derived from the same `_ROW` padding
    `mltcp_cc_tick` applies, so the expectation and the dispatch can
    never drift apart silently.
    """
    return ms.expected_layout(packed_rows(n_flows),
                              use_static_factors=use_static_factors)


def _pack(x, n_pad, fill=0.0, dtype=jnp.float32):
    x = jnp.asarray(x, dtype)
    x = jnp.pad(x, (0, n_pad - x.shape[0]), constant_values=fill)
    return x.reshape(n_pad // ms.LANES, ms.LANES)


def mltcp_cc_tick(cfg: core.MLTCPConfig, state: core.MLTCPState,
                  fb: core.Feedback, total_bytes: Array,
                  flow_to_job: Optional[Array] = None, n_jobs: int = 0,
                  static_factors: Optional[Array] = None,
                  comm_elapsed: Optional[Array] = None,
                  est_finish: Optional[Array] = None,
                  dyn: Optional[core.DynamicParams] = None,
                  interpret: Optional[bool] = None
                  ) -> tuple[core.MLTCPState, Array]:
    """core.cc_tick drop-in backed by the fused Pallas kernel.

    The protocol scalars (``dyn``, default: the config's floats) and the
    Static-baseline per-flow ``static_factors`` travel into the kernel as
    *operands* — an f32[NDYN] SMEM ref and an [R, 128] lanes ref — so
    traced sweep values (`simulate_sweep`'s vmapped K axis) run fused, one
    program per compile group.  Only structural options the kernel does not
    implement (non-default favoritism, non-linear F family) fall back to
    the jnp oracle; the fallback is loud (``FALLBACK_COUNT`` + one-time
    warning) so ``use_pallas_kernel=True`` can never silently run unfused.
    """
    # Static [67] factors replace F(score) per flow (negative entries are
    # the "adaptive" sentinel — see core.cc_tick), so with all-non-negative
    # factors favoritism/f_spec are moot and must not force a fallback for
    # a Static-baseline arm of an ablation plan.  Sentinel entries reuse
    # the kernel's adaptive branch, which implements only the default
    # linear F over largest_data_sent; the experiment layer therefore
    # never merges Static and adaptive points into one kernel-enabled
    # group unless that default applies (experiment._compile_groups).
    reason = None
    if static_factors is None:
        if cfg.favoritism != "largest_data_sent":
            reason = f"favoritism={cfg.favoritism!r}"
        elif cfg.f_spec != "linear":
            reason = f"f_spec={cfg.f_spec!r}"
    if reason is not None:
        global FALLBACK_COUNT
        FALLBACK_COUNT += 1
        if reason not in _FALLBACK_WARNED:
            _FALLBACK_WARNED.add(reason)
            warnings.warn(
                f"mltcp_cc_tick: option {reason} is outside the fused "
                f"kernel's static specialization; falling back to the jnp "
                f"oracle (use_pallas_kernel has no effect for this config)",
                stacklevel=2)
        return core.cc_tick(cfg, state, fb, total_bytes,
                            flow_to_job=flow_to_job, n_jobs=n_jobs,
                            static_factors=static_factors,
                            comm_elapsed=comm_elapsed,
                            est_finish=est_finish, dyn=dyn)
    if dyn is None:
        dyn = core.DynamicParams.from_config(cfg)
    # operand-carried protocol scalars, packed per ms.DYN_FIELDS (==
    # DynamicParams order); concrete floats and traced sweep values take
    # the same path
    dyn_vec = jnp.stack([jnp.asarray(v, jnp.float32) for v in dyn])

    n = state.cc.cwnd.shape[0]
    # Per-flow operands must be rank-1 [N]: the engine-level layers above
    # (fault injection most recently — netsim.faults applies its event
    # tables *before* the CC tick) gather/reduce to flow vectors, and a
    # table leaking through unreduced (e.g. [E, N]) would silently pack
    # garbage rows into lanes.  Fail structurally instead.
    for op_name, op in (("total_bytes", total_bytes),
                        ("static_factors", static_factors),
                        ("comm_elapsed", comm_elapsed),
                        ("est_finish", est_finish)):
        if op is None:
            continue
        shape = jnp.shape(op)
        # a static shape tuple, not a traced value:
        if shape not in ((), (n,)):  # lint: allow(branch-on-traced)
            raise ValueError(
                f"mltcp_cc_tick: operand {op_name!r} has shape {shape}, "
                f"expected scalar or [N]={n} per-flow; an engine-level "
                f"layer (fault event table?) leaked an unreduced array "
                f"into the CC tick")
    n_pad = -(-n // _ROW) * _ROW

    # job-aggregated numerator (paper §4.1: stats aggregated per job);
    # iteration.ack_bytes pins the product's rounding (see its docstring) —
    # the same materialized array feeds the kernel's ack_bytes operand
    ackb = iteration.ack_bytes(fb.num_acks, cfg.cc.mss)
    per_flow_bytes = state.det.bytes_sent + ackb
    if cfg.aggregate_by_job and flow_to_job is not None and n_jobs > 0:
        job_tot = jnp.zeros((n_jobs,), per_flow_bytes.dtype
                            ).at[flow_to_job].add(per_flow_bytes)
        job_numer = job_tot[flow_to_job]
        aggregate = True
    else:
        job_numer = per_flow_bytes
        aggregate = False

    cc = cfg.cc
    p = {
        "algo": int(cc.algo), "variant": int(cc.variant),
        "mss": cc.mss, "rtt": cc.rtt, "tick_dt": cc.tick_dt,
        "min_cwnd": cc.min_cwnd, "reno_beta": cc.reno_beta,
        "cubic_c": cc.cubic_c, "cubic_beta": cc.cubic_beta,
        "cubic_scale": cc.cubic_scale, "line_rate": cc.line_rate,
        "rate_ai": cc.rate_ai, "rate_min": cc.rate_min,
        "dcqcn_g": cc.dcqcn_g, "alpha_timer": cc.alpha_timer,
        "inc_timer": cc.inc_timer, "cnp_interval": cc.cnp_interval,
        "fast_recovery_stages": cc.fast_recovery_stages,
        "aggregate": aggregate,
    }

    d, c = state.det, state.cc
    now_arr = jnp.broadcast_to(jnp.asarray(fb.now, jnp.float32), (n,))
    arrays = {
        "bytes_sent": _pack(d.bytes_sent, n_pad),
        "prev_ack_tstamp": _pack(d.prev_ack_tstamp, n_pad),
        "iter_gap": _pack(d.iter_gap, n_pad, fill=1.0),
        "max_gap": _pack(d.max_gap, n_pad, fill=1.0),
        "cwnd": _pack(c.cwnd, n_pad, fill=1.0),
        "ssthresh": _pack(c.ssthresh, n_pad, fill=1.0),
        "cooldown": _pack(c.cooldown, n_pad),
        "w_max": _pack(c.w_max, n_pad, fill=1.0),
        "epoch_start": _pack(c.epoch_start, n_pad),
        "rate_cur": _pack(c.rate_cur, n_pad, fill=cc.rate_min),
        "rate_target": _pack(c.rate_target, n_pad, fill=cc.rate_min),
        "alpha": _pack(c.alpha, n_pad),
        "t_last_cnp": _pack(c.t_last_cnp, n_pad),
        "t_last_inc": _pack(c.t_last_inc, n_pad),
        "t_last_alpha": _pack(c.t_last_alpha, n_pad),
        "stage": _pack(c.inc_stage, n_pad, dtype=jnp.int32),
        "prev_ratio": _pack(d.bytes_ratio, n_pad),
        "num_acks": _pack(fb.num_acks, n_pad),
        "ack_bytes": _pack(ackb, n_pad),
        "loss": _pack(fb.loss, n_pad),
        "cnp": _pack(fb.cnp, n_pad),
        "now": _pack(now_arr, n_pad),
        "total_bytes": _pack(total_bytes, n_pad, fill=1.0),
        "job_numer": _pack(job_numer, n_pad),
    }
    factors = (None if static_factors is None
               else _pack(static_factors, n_pad, fill=1.0))
    out = ms.mltcp_tick_arrays(p, dyn_vec, arrays, static_factors=factors,
                               interpret=_resolve_interpret(interpret))

    def unpack(x, dtype=jnp.float32):
        return x.reshape(-1)[:n].astype(dtype)

    # boundary counter (metrics-only) maintained outside the kernel, via the
    # same predicate helper the jnp oracle uses (single source of truth)
    boundary = iteration.boundary_mask(d.prev_ack_tstamp, d.iter_gap, dyn.g,
                                       fb.num_acks, fb.now)

    det = core.MLTCPState(
        cc=state.cc, det=state.det).det._replace(
        bytes_sent=unpack(out["bytes_sent"]),
        bytes_ratio=unpack(out["ratio"]),
        prev_ack_tstamp=unpack(out["prev_ack_tstamp"]),
        iter_gap=unpack(out["iter_gap"]),
        max_gap=unpack(out["max_gap"]),
        n_boundaries=d.n_boundaries + boundary.astype(jnp.int32),
    )
    ccs = state.cc._replace(
        cwnd=unpack(out["cwnd"]),
        ssthresh=unpack(out["ssthresh"]),
        cooldown=unpack(out["cooldown"]),
        w_max=unpack(out["w_max"]),
        epoch_start=unpack(out["epoch_start"]),
        rate_cur=unpack(out["rate_cur"]),
        rate_target=unpack(out["rate_target"]),
        alpha=unpack(out["alpha"]),
        t_last_cnp=unpack(out["t_last_cnp"]),
        t_last_inc=unpack(out["t_last_inc"]),
        t_last_alpha=unpack(out["t_last_alpha"]),
        inc_stage=unpack(out["stage"], jnp.int32),
    )
    rate = unpack(out["rate"])
    return core.MLTCPState(cc=ccs, det=det), rate
