"""RG-LRU linear-recurrence scan as a Pallas TPU kernel.

Computes h_t = a_t * h_{t-1} + b_t along time for [B, T, D] gate/input
arrays.  Tiling: grid = (B, D/BLOCK_D) — both parallel — with the full time
axis resident in VMEM per block ((T, 128) f32 = 2 MiB at T=4096) and a
sequential fori_loop walking time.  The TPU-native choice per the brief:
the recurrence is diagonal, so channels are independent lanes (VPU-friendly
128-wide), and blocking over (batch, channel) gives perfect parallelism
while HBM traffic stays at 2 reads + 1 write per element.

Oracle: ref.py's associative-scan formulation (identical math, log-depth).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 128


def _rg_lru_kernel(a_ref, b_ref, h0_ref, o_ref, *, t_len: int):
    h = h0_ref[0]                                        # [bd]

    def body(t, h):
        h = a_ref[0, t] * h + b_ref[0, t]
        o_ref[0, t] = h
        return h

    jax.lax.fori_loop(0, t_len, body, h)


def rg_lru_scan(a, b, h0=None, *, block_d: int = BLOCK_D,
                interpret: bool = True):
    """a, b: [B, T, D]; h0: [B, D] or None -> h: [B, T, D]."""
    bsz, t, d = a.shape
    assert d % block_d == 0, (d, block_d)
    if h0 is None:
        h0 = jnp.zeros((bsz, d), a.dtype)

    kernel = functools.partial(_rg_lru_kernel, t_len=t)
    return pl.pallas_call(
        kernel,
        grid=(bsz, d // block_d),
        in_specs=[
            pl.BlockSpec((1, t, block_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, t, block_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, block_d), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, t, block_d), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, t, d), a.dtype),
        interpret=interpret,
    )(a, b, h0)
