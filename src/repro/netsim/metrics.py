"""Metrics over raw simulation outputs — the paper's reported quantities.

* per-job training-iteration times (avg / p99 / CDF)  — Figs 7c, 8c, 9c, 11
* dropped / ECN-marked packets per second             — Figs 7b, 8b, 9b
* link-utilization traces                             — Figs 7a, 8a, 9a, 14
* interleave score: pairwise Jaccard overlap of comm phases on shared links
* speedups vs a baseline run                          — Figs 10, 12, 13
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np

from repro.netsim import telemetry as telemetry_mod
from repro.netsim.engine import RawSimOutput, SimConfig, SweepPoint


@dataclasses.dataclass
class SimResult:
    """Post-processed, numpy-side view of one simulation.

    ``point`` (when the run came from a sweep or an experiment plan) names
    the grid point this result belongs to — axis name -> value labels plus
    the resolved `SweepParams` — so results are self-describing and can be
    grouped/pivoted by axis name instead of positional bookkeeping.
    """

    cfg: SimConfig
    iter_times: list[np.ndarray]      # per job, valid entries only
    drops_per_s: float
    marks_per_s: float
    trace_t: np.ndarray               # [C]
    trace_util: np.ndarray            # [C, M]
    trace_incomm: np.ndarray          # [C, J]
    trace_drops: np.ndarray           # [C]
    trace_jobtput: np.ndarray         # [C, J] delivered bytes/s per job
    point: Optional[SweepPoint] = None
    # decimated probe series + detector outputs when cfg.telemetry armed
    # the probe subsystem (netsim.telemetry); None otherwise
    telemetry: Optional[telemetry_mod.TelemetryResult] = None

    @property
    def n_jobs(self) -> int:
        return len(self.iter_times)

    def avg_iter(self, job: int, warmup: int = 5) -> float:
        x = self.iter_times[job][warmup:]
        return float(np.mean(x)) if x.size else float("nan")

    def p99_iter(self, job: int, warmup: int = 5) -> float:
        x = self.iter_times[job][warmup:]
        return float(np.percentile(x, 99)) if x.size else float("nan")

    def all_iters(self, warmup: int = 5) -> np.ndarray:
        xs = [x[warmup:] for x in self.iter_times if x.size > warmup]
        return np.concatenate(xs) if xs else np.asarray([])


def postprocess(cfg: SimConfig, raw: RawSimOutput,
                point: Optional[SweepPoint] = None,
                n_jobs: Optional[int] = None) -> SimResult:
    """Numpy-side view of one raw simulation.

    ``point`` attaches the sweep/plan coordinates; ``n_jobs`` trims the
    job-indexed outputs to the first n jobs — the active jobs of a run on a
    padded fabric (`SweepParams.job_active`), whose masked-off trailing jobs
    record no iterations and carry no traffic.
    """
    it = np.asarray(raw.iter_times)
    counts = np.asarray(raw.iter_counts)
    n = it.shape[0] if n_jobs is None else min(n_jobs, it.shape[0])
    per_job = [it[j, : int(min(counts[j], it.shape[1]))] for j in range(n)]
    per_job = [x[~np.isnan(x)] for x in per_job]
    sim_t = float(np.asarray(raw.trace_t)[-1]) if raw.trace_t.size else cfg.sim_time
    telemetry = None
    if raw.telemetry is not None and cfg.telemetry is not None:
        telemetry = telemetry_mod.collect(cfg, raw.telemetry, n_jobs=n)
    return SimResult(
        cfg=cfg,
        iter_times=per_job,
        drops_per_s=float(np.asarray(raw.trace_drops).sum() / max(sim_t, 1e-9)),
        marks_per_s=float(np.asarray(raw.trace_marks).sum() / max(sim_t, 1e-9)),
        trace_t=np.asarray(raw.trace_t),
        trace_util=np.asarray(raw.trace_util),
        trace_incomm=np.asarray(raw.trace_incomm)[:, :n],
        trace_drops=np.asarray(raw.trace_drops),
        trace_jobtput=np.asarray(raw.trace_jobtput)[:, :n],
        point=point,
        telemetry=telemetry,
    )


def postprocess_sweep(cfg: SimConfig, raw: RawSimOutput,
                      points: Optional[list[SweepPoint]] = None
                      ) -> list[SimResult]:
    """Post-process a `simulate_sweep` output (leading [K] sweep axis) into
    one SimResult per grid point, in sweep order.

    Pass the `SweepPoint` list from `grid_sweep` (or hand-built labels) and
    each result carries its own point — downstream grouping then selects by
    axis value instead of relying on positional alignment.
    """
    k = int(np.asarray(raw.iter_counts).shape[0])
    if points is not None and len(points) != k:
        raise ValueError(f"{len(points)} points for a K={k} sweep")
    return [postprocess(cfg, jax.tree_util.tree_map(lambda x, i=i: x[i], raw),
                        point=None if points is None else points[i],
                        n_jobs=None if points is None else points[i].n_jobs)
            for i in range(k)]


def iteration_times(cfg: SimConfig, raw: RawSimOutput) -> list[np.ndarray]:
    return postprocess(cfg, raw).iter_times


def interleave_score(res: SimResult, job_a: int, job_b: int,
                     tail_frac: float = 0.5) -> float:
    """Jaccard overlap of two jobs' comm phases over the trace tail.

    0.0 = perfectly interleaved, 1.0 = fully synchronized. The paper's
    convergence claim: MLTCP drives this toward ~0 within ~10 iterations,
    so we score the tail (post-convergence) portion of the run.
    """
    ic = res.trace_incomm
    start = int(ic.shape[0] * (1.0 - tail_frac))
    a = ic[start:, job_a].astype(bool)
    b = ic[start:, job_b].astype(bool)
    union = np.logical_or(a, b).sum()
    if union == 0:
        return 0.0
    return float(np.logical_and(a, b).sum() / union)


def mean_pairwise_interleave(res: SimResult, tail_frac: float = 0.5) -> float:
    j = res.trace_incomm.shape[1]
    scores = [interleave_score(res, a, b, tail_frac)
              for a in range(j) for b in range(a + 1, j)]
    return float(np.mean(scores)) if scores else 0.0


def speedup_stats(base: SimResult, test: SimResult,
                  warmup: int = 5) -> dict[str, float]:
    """Training-iteration-time speedups of ``test`` over ``base`` (paper's
    headline metric): ratio of avg and p99 iteration times across all jobs."""
    b, t = base.all_iters(warmup), test.all_iters(warmup)
    return {
        "avg_speedup": float(np.mean(b) / np.mean(t)),
        "p99_speedup": float(np.percentile(b, 99) / np.percentile(t, 99)),
        "base_avg": float(np.mean(b)), "test_avg": float(np.mean(t)),
        "base_p99": float(np.percentile(b, 99)),
        "test_p99": float(np.percentile(t, 99)),
    }


def sweep_speedup_stats(bases: list[SimResult], tests: list[SimResult],
                        warmup: int = 5) -> dict[str, float]:
    """Seed-paired speedups over a sweep: ``bases``/``tests`` are same-length
    `postprocess_sweep` outputs run with matching seed grids; returns mean
    and (population) std across the sweep — the paper-figure error bars."""
    if len(bases) != len(tests):
        raise ValueError(f"sweep lengths differ: {len(bases)} vs {len(tests)}")
    per = [speedup_stats(b, t, warmup) for b, t in zip(bases, tests)]
    avg = np.asarray([p["avg_speedup"] for p in per])
    p99 = np.asarray([p["p99_speedup"] for p in per])
    return {
        "avg_speedup": float(avg.mean()), "avg_speedup_std": float(avg.std()),
        "p99_speedup": float(p99.mean()), "p99_speedup_std": float(p99.std()),
        "n_points": len(per),
    }


# ---------------------------------------------------------------------------
# Telemetry accessors (probe series + detector outputs; netsim.telemetry)
# ---------------------------------------------------------------------------

def _require_telemetry(res: SimResult) -> telemetry_mod.TelemetryResult:
    if res.telemetry is None:
        raise ValueError(
            "result has no telemetry: run with SimConfig.telemetry set to a "
            "TelemetrySpec (or run_plan(..., telemetry=spec))")
    return res.telemetry


def probe_timeline(res: SimResult, probe: str
                   ) -> tuple[np.ndarray, np.ndarray]:
    """(t, values) of one armed probe's decimated series — e.g.
    ``probe_timeline(res, "flow_cwnd")`` gives the Fig. 5-style [S, N]
    per-flow cwnd timeline at sample times t [S]."""
    return _require_telemetry(res).timeline(probe)


def time_to_interleave(res: SimResult) -> float:
    """Seconds until the EWMA pairwise comm-overlap *permanently* drops
    below the spec's threshold (inf if the run never converged — the
    paper's "stabilizes into an interleaved state" claim, as a number)."""
    return _require_telemetry(res).time_to_interleave_s


def convergence_iteration(res: SimResult) -> float:
    """Training iterations completed when the interleave detector last saw
    overlap above threshold — the paper's "within a few training
    iterations" metric (inf: never converged; 0: interleaved from the
    start)."""
    return _require_telemetry(res).time_to_interleave_iters


def iter_time_quantile(res: SimResult, q: float,
                       job: Optional[int] = None) -> float:
    """Streaming iteration-time quantile from the in-scan log-histogram
    sketch (no dense iteration record needed; ~one-bin resolution)."""
    return _require_telemetry(res).iter_quantile(q, job=job)
