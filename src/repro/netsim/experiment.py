"""Declarative experiment plans — one sweep surface over every axis.

The paper's evaluation is a matrix of sweeps: Fig. 10 varies job count x
seed, Figs. 15-17 vary aggressiveness functions and protocol scalars, the
baselines add scheme axes (OFF / WI / MD / Static / Cassini).  Some of those
axes are *dynamic* (traced scalars the batched sweep engine already vmaps
over — slope, intercept, g, gamma, RED thresholds, seeds, per-job factors,
the `job_active` mask) and some are *static* (they shape the traced program
— algorithm, variant, F family, topology, workload).  Before this module
every benchmark hand-wired that split; now callers declare a `Plan`:

    plan = Plan(
        name="fig10-reno",
        axes=(Axis("variant", ("OFF", "WI")),
              Axis("n_jobs", (2, 3, 4, 5, 6, 7, 8)),
              Axis("seed", (1, 2, 3))),
        build=lambda pt: build_cfg_for(pt["variant"], pt["n_jobs"]),
    )
    result = run_plan(plan)
    sweep_speedup_stats(result.select(variant="OFF", n_jobs=4),
                        result.select(variant="WI", n_jobs=4))

and `run_plan` does the partitioning (DESIGN.md §5):

  1. enumerate the cartesian product of the axes (minus `where`-filtered
     points) and build each point's `SimConfig`;
  2. group points by *static signature* — the config with every dynamic
     field canonicalized, workload *values* (phase programs, straggle
     probabilities, Cassini schedules, Static factors) included — so
     points that only differ dynamically share one compile group;
  3. merge groups that differ only in workload *shape*: if a point's
     (topology, job structure) equal the *restriction* of a larger
     point's to its first n jobs, the smaller point runs on the larger
     fabric with a `job_active` mask (the padded-jobs axis), joining its
     compile group; phase programs are column-padded to the group's
     P_max (zero columns are inert under the `n_phases` mask);
  4. lower each group's points onto the `simulate_sweep` K axis — one
     trace, one compile, K simulations per group — optionally sharding K
     across local devices;
  5. post-process each point with its own (unpadded) config and attach a
     `SweepPoint`, so every `SimResult` names its axis coordinates.

A Fig. 10-style plan (7 job counts x 3 seeds x {OFF, WI}) thus compiles
*two* programs (one per variant) instead of 14+, and the straggler /
partial-compat grids (which sweep workload values) collapse the same way.

``run_plan(..., cache_dir=...)`` adds a SweepPoint-keyed on-disk cache:
each point's result is stored under a content hash of its full config and
resolved dynamic overrides, so interrupted benchmark runs resume and
figures re-aggregate without re-simulating.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import os
import pickle
import time
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.netsim import counters
from repro.netsim import engine as engine_mod
from repro.netsim import metrics
from repro.netsim.engine import (
    SimConfig,
    JobSpec,
    SweepParams,
    SweepPoint,
    simulate_sweep,
    sweep_of,
)
from repro.netsim.telemetry import TelemetrySpec
from repro.netsim.topology import Topology

__all__ = ["Axis", "Plan", "PlanResult", "GroupError", "GroupProfile",
           "PlanProfile", "run_plan", "prune_cache", "restrict_workload",
           "resolve_plan", "group_sweep"]

_DYNAMIC_FIELDS = frozenset(SweepParams._fields)


# ---------------------------------------------------------------------------
# Plan declaration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Axis:
    """One named dimension of an experiment plan.

    ``values`` are the labels enumerated into the cartesian product; every
    point's full label dict is passed to `Plan.build`.

    kind:
      * "dynamic" — the axis targets a `SweepParams` field and rides the
        batched sweep (no recompilation across its values);
      * "static"  — the axis only shapes the config via `Plan.build`
        (algorithm, variant, F family, workload, ...);
      * "auto"    — dynamic iff the target field names a SweepParams field.

    ``field`` overrides the targeted SweepParams field (default: the axis
    name), and ``resolve`` maps a label to the field's actual value — e.g.
    an axis named "solo" with values ("all", 0, 1) can resolve to
    `job_active` masks while results stay selectable by the human label.

    ``field="*"`` targets *several* sweep fields at once: the resolved
    value must be a ``{sweep field: value}`` dict — or a callable taking
    the point's built `SimConfig` and returning one, for values whose
    shapes depend on the config (a fault schedule's blackhole table is
    [E, n_flows], and n_flows varies with the point's socket counts).
    The fault-schedule axis of `benchmarks/churn.py` is the canonical use:
    one human label resolves to the whole ``faults.FaultSchedule
    .overrides()`` dict, so schedules ride the batched sweep.
    """

    name: str
    values: tuple
    kind: str = "auto"
    field: Optional[str] = None
    resolve: Optional[Callable[[object], object]] = None

    def __post_init__(self):
        if self.kind not in ("auto", "dynamic", "static"):
            raise ValueError(f"axis {self.name!r}: unknown kind {self.kind!r}")
        if not len(self.values):
            raise ValueError(f"axis {self.name!r} has no values")
        object.__setattr__(self, "values", tuple(self.values))

    @property
    def target(self) -> str:
        return self.field if self.field is not None else self.name

    def is_dynamic(self) -> bool:
        if self.kind == "auto":
            return self.target == "*" or self.target in _DYNAMIC_FIELDS
        return self.kind == "dynamic"


@dataclasses.dataclass(frozen=True)
class Plan:
    """A declarative experiment: named axes x a config builder.

    ``build`` receives one point's ``{axis name: value}`` dict and returns
    that point's `SimConfig`.  It may ignore dynamic axes entirely —
    `run_plan` threads their (resolved) values into the sweep afterwards —
    but static axes (job count, scheme, F family, ...) must be reflected in
    the returned config.  ``where`` optionally prunes points from the
    cartesian product (e.g. baseline points that only need one slope).
    """

    axes: tuple[Axis, ...]
    build: Callable[[dict], SimConfig]
    name: str = ""
    where: Optional[Callable[[dict], bool]] = None

    def __post_init__(self):
        names = [ax.name for ax in self.axes]
        if len(set(names)) != len(names):
            raise ValueError(f"plan {self.name!r}: duplicate axis names {names}")

    def points(self) -> list[dict]:
        """The cartesian product of axis values (last axis fastest), minus
        `where`-filtered points, as one label dict per point."""
        pts = [{}]
        for ax in self.axes:
            pts = [{**p, ax.name: v} for p in pts for v in ax.values]
        if self.where is not None:
            pts = [p for p in pts if self.where(p)]
        if not pts:
            raise ValueError(f"plan {self.name!r} has no points")
        return pts


# ---------------------------------------------------------------------------
# Workload restriction — the padded-jobs merge test
# ---------------------------------------------------------------------------

def restrict_workload(topo: Topology, jobs: JobSpec,
                      n_jobs: int) -> tuple[Topology, JobSpec]:
    """The sub-workload on the first ``n_jobs`` jobs of a fabric.

    A smaller plan point may run on a larger point's fabric (with trailing
    jobs masked off) exactly when its own (topo, jobs) equal this
    restriction — same links, same flows for the kept jobs, same phase
    programs.  Flows of kept jobs must form a prefix of the flow axis so
    the lane-stable RNG draws identical randomness (see `_lane_uniform`).
    """
    keep = topo.flow_to_job < n_jobs
    topo_r = Topology(cap=topo.cap, hops=topo.hops[keep],
                      flow_to_job=topo.flow_to_job[keep], names=topo.names)
    jobs_r = JobSpec(compute=jobs.compute[:n_jobs],
                     comm_bytes=jobs.comm_bytes[:n_jobs],
                     n_phases=jobs.n_phases[:n_jobs],
                     start_offset=jobs.start_offset[:n_jobs],
                     straggle_prob=jobs.straggle_prob[:n_jobs],
                     iso_iter_time=jobs.iso_iter_time[:n_jobs])
    return topo_r, jobs_r


def _pad_cols(a: np.ndarray, width: int, fill) -> np.ndarray:
    if a.shape[1] >= width:
        return a
    pad = np.full((a.shape[0], width - a.shape[1]), fill, a.dtype)
    return np.concatenate([a, pad], axis=1)


def _same_workload(ta: Topology, ja: JobSpec, tb: Topology, jb: JobSpec) -> bool:
    """Value equality modulo behaviour-neutral padding (zero phase columns,
    -1 hop columns)."""
    if ta.names != tb.names or not np.array_equal(ta.cap, tb.cap):
        return False
    if not np.array_equal(ta.flow_to_job, tb.flow_to_job):
        return False
    h = max(ta.hops.shape[1], tb.hops.shape[1])
    if not np.array_equal(_pad_cols(ta.hops, h, -1), _pad_cols(tb.hops, h, -1)):
        return False
    p = max(ja.compute.shape[1], jb.compute.shape[1])
    return (np.array_equal(_pad_cols(ja.compute, p, 0.0),
                           _pad_cols(jb.compute, p, 0.0))
            and np.array_equal(_pad_cols(ja.comm_bytes, p, 0.0),
                               _pad_cols(jb.comm_bytes, p, 0.0))
            and np.array_equal(ja.n_phases, jb.n_phases)
            and np.array_equal(ja.start_offset, jb.start_offset)
            and np.array_equal(ja.straggle_prob, jb.straggle_prob)
            and np.array_equal(ja.iso_iter_time, jb.iso_iter_time))


def _flows_are_job_prefix(topo: Topology, n_jobs: int) -> bool:
    """Flows of the first n_jobs jobs occupy the first flow lanes."""
    keep = topo.flow_to_job < n_jobs
    return bool(np.all(np.nonzero(keep)[0] == np.arange(int(keep.sum()))))


# ---------------------------------------------------------------------------
# Static signatures & compile groups
# ---------------------------------------------------------------------------

def _canonical_jobs(jobs: JobSpec) -> JobSpec:
    """The job structure with every traced workload value zeroed.

    Phase-program values, straggle probabilities and isolation times ride
    the sweep (`SweepParams.compute` / `comm_bytes` / `straggle_prob` /
    `iso_iter`); only the array shapes, `n_phases` and `start_offset`
    remain structural.
    """
    return JobSpec(compute=np.zeros_like(jobs.compute),
                   comm_bytes=np.zeros_like(jobs.comm_bytes),
                   n_phases=jobs.n_phases,
                   start_offset=jobs.start_offset,
                   straggle_prob=np.zeros_like(jobs.straggle_prob),
                   iso_iter_time=np.zeros_like(jobs.iso_iter_time))


def _canonical_cfg(cfg: SimConfig) -> SimConfig:
    """The config with every dynamic field pinned to a canonical value.

    Two points share a compile group iff their canonical configs are equal
    (after workload-shape merging); using the canonical config as the jit
    static argument also means re-running a plan with different seeds,
    scalars or workload values hits the exact same jit cache entry.

    The Static factors and the Cassini schedule canonicalize to None —
    their values are `SweepParams` leaves and their *presence* is
    normalized per group at lowering time (`_point_params`): a point
    without factors gets the all-negative "adaptive" sentinel, a point
    without a schedule gets all-zero periods (per-job off), both exact
    value-level no-ops in the traced program.
    """
    proto = dataclasses.replace(cfg.protocol, slope=0.0, intercept=0.0,
                                g=0.0, gamma=0.0, init_comm_gap=0.0)
    return dataclasses.replace(
        cfg, protocol=proto, seed=0,
        red_qmin=0.0, red_qmax=1.0, red_pmax=0.0,
        jobs=_canonical_jobs(cfg.jobs),
        static_job_factors=None, cassini=None)


def _no_workload(cfg: SimConfig) -> SimConfig:
    return dataclasses.replace(cfg, topo=None, jobs=None)


def _fabric_key(topo: Topology):
    return (topo.names, topo.cap.tobytes())


def _factors_need_split(cfg: SimConfig) -> bool:
    """True when Static-factor presence may not be mixed in one group.

    The fused kernel's adaptive branch (which sentinel factor entries
    select) implements only the default linear F over largest_data_sent;
    under any other structural option a kernel-enabled group must keep
    factor-bearing and adaptive points apart so no sentinel ever reaches
    the kernel (pure-Static groups stay fused — all entries >= 0 mask the
    branch exactly).  The jnp oracle computes the true adaptive F, so
    non-kernel configs always mix.
    """
    return cfg.use_pallas_kernel and (
        cfg.protocol.f_spec != "linear"
        or cfg.protocol.favoritism != "largest_data_sent")


@dataclasses.dataclass
class _Group:
    """One compile group: a shared static config + its member points."""

    cfg: SimConfig               # canonical static config (largest fabric,
    #                              phase programs padded to the group P_max)
    idxs: list[int]              # plan-point indices, in plan order
    masked: bool                 # True iff job_active masks are needed
    factors: bool = False        # some member carries Static factors
    cassini: bool = False        # some member carries a Cassini schedule


def _pad_group_jobs(jobs: JobSpec, p_max: int) -> JobSpec:
    if jobs.compute.shape[1] >= p_max:
        return jobs
    return JobSpec(compute=_pad_cols(jobs.compute, p_max, 0.0),
                   comm_bytes=_pad_cols(jobs.comm_bytes, p_max, 0.0),
                   n_phases=jobs.n_phases,
                   start_offset=jobs.start_offset,
                   straggle_prob=jobs.straggle_prob,
                   iso_iter_time=jobs.iso_iter_time)


def _finish_group(cfgs: list[SimConfig], cfg_g: SimConfig,
                  members: list[int], masked: bool) -> _Group:
    p_max = max(cfgs[i].jobs.compute.shape[1] for i in members)
    if cfg_g.jobs.compute.shape[1] < p_max:
        cfg_g = dataclasses.replace(
            cfg_g, jobs=_pad_group_jobs(cfg_g.jobs, p_max))
    return _Group(cfg=cfg_g, idxs=sorted(members), masked=masked,
                  factors=any(cfgs[i].static_job_factors is not None
                              for i in members),
                  cassini=any(cfgs[i].cassini is not None for i in members))


def _compile_groups(cfgs: list[SimConfig], pad_jobs: bool) -> list[_Group]:
    canon = [_canonical_cfg(c) for c in cfgs]
    # Bucket by everything except the workload, then merge by workload
    # *shape* (the canonical jobs' zeroed values make `_same_workload` a
    # structural comparison).  Factor presence joins the key only when the
    # kernel cannot take the adaptive sentinel (_factors_need_split).
    buckets: dict = {}
    for i, c in enumerate(canon):
        fp = (cfgs[i].static_job_factors is not None
              if _factors_need_split(c) else None)
        if pad_jobs:
            key = ("pad", _no_workload(c), _fabric_key(c.topo), fp)
        else:
            key = ("exact", c, fp)
        buckets.setdefault(key, []).append(i)

    groups: list[_Group] = []
    for key, idxs in buckets.items():
        if key[0] == "exact":
            groups.append(_finish_group(cfgs, canon[idxs[0]], idxs,
                                        masked=False))
            continue
        remaining = list(idxs)
        while remaining:
            ref = max(remaining,
                      key=lambda i: (cfgs[i].jobs.n_jobs, cfgs[i].topo.n_flows))
            ref_topo, ref_jobs = cfgs[ref].topo, canon[ref].jobs
            members, rest = [], []
            for i in remaining:
                n = cfgs[i].jobs.n_jobs
                if (n <= ref_jobs.n_jobs
                        and _flows_are_job_prefix(ref_topo, n)
                        and _same_workload(*restrict_workload(ref_topo,
                                                              ref_jobs, n),
                                           cfgs[i].topo, canon[i].jobs)):
                    members.append(i)
                else:
                    rest.append(i)
            masked = any(cfgs[i].jobs.n_jobs < ref_jobs.n_jobs
                         for i in members)
            groups.append(_finish_group(cfgs, canon[ref], members, masked))
            remaining = rest
    # deterministic group order: by first member point
    groups.sort(key=lambda g: g.idxs[0])
    return groups


# ---------------------------------------------------------------------------
# Lowering a group onto the sweep axis
# ---------------------------------------------------------------------------

def _pad_rows(x: np.ndarray, j: int, fill) -> np.ndarray:
    if x.shape[0] >= j:
        return x
    pad = np.full((j - x.shape[0],) + x.shape[1:], fill, x.dtype)
    return np.concatenate([x, pad], axis=0)


def _point_params(cfg: SimConfig, overrides: dict, group: _Group) -> SweepParams:
    """Resolve one point's unbatched SweepParams on the group's fabric.

    Scalar overrides of per-job fields broadcast across the point's own
    jobs; the workload leaves are then padded to the group's [J_ref, P_max]
    shape (zero rows for masked-off jobs, zero columns beyond `n_phases`);
    Static-factor / Cassini presence is normalized group-wide with exact
    value-level no-ops (the adaptive sentinel, zero periods).
    """
    from repro.netsim.engine import (  # single source of dtypes/shapes
        _FIELD_DTYPE,
        _point_shape,
    )

    params = sweep_of(cfg)
    for field, value in overrides.items():
        dtype = _FIELD_DTYPE.get(field, jnp.float32)
        a = np.asarray(value)
        shape = _point_shape(field, cfg)
        if a.ndim < len(shape):
            a = np.broadcast_to(a, shape)
        params = params._replace(**{field: jnp.asarray(a, dtype)})
    j_ref = group.cfg.jobs.n_jobs
    p_max = group.cfg.jobs.compute.shape[1]
    n = cfg.jobs.n_jobs

    def pad(x, fill=0.0, cols=False):
        x = np.asarray(x, np.float32)
        if cols:
            x = _pad_cols(x, p_max, 0.0)
        return jnp.asarray(_pad_rows(x, j_ref, fill))

    params = params._replace(
        compute=pad(params.compute, cols=True),
        comm_bytes=pad(params.comm_bytes, cols=True),
        straggle_prob=pad(params.straggle_prob),
        iso_iter=pad(params.iso_iter),
    )
    if group.factors:
        f = params.static_job_factors
        f = (np.full((n,), -1.0, np.float32) if f is None  # adaptive sentinel
             else np.asarray(f, np.float32))
        params = params._replace(static_job_factors=pad(f, fill=1.0))
    if group.cassini:
        off = params.cassini_offset
        per = params.cassini_period
        eps = params.cassini_eps
        off = np.zeros((n,), np.float32) if off is None else np.asarray(off)
        per = np.zeros((n,), np.float32) if per is None else np.asarray(per)
        params = params._replace(
            cassini_offset=pad(off), cassini_period=pad(per),
            cassini_eps=jnp.asarray(0.0 if eps is None else eps, jnp.float32))
    if params.job_active is not None:
        m = np.asarray(params.job_active, bool)
        if m.shape[0] < j_ref:     # caller mask on the point's own fabric
            m = np.concatenate([m, np.zeros((j_ref - m.shape[0],), bool)])
        params = params._replace(job_active=jnp.asarray(m))
    elif group.masked:
        mask = np.zeros((j_ref,), bool)
        mask[:n] = True
        params = params._replace(job_active=jnp.asarray(mask))
    if cfg.faults is not None:
        # fault tables are built on the point's own fabric; pad the job /
        # flow axis to the group's with identity values for the padded
        # lanes (inactive jobs stay inactive, padded flows never
        # blackhole).  Links are never padded — the pad-merge requires an
        # identical link fabric.  cfg.faults rides the canonical config,
        # so presence is uniform within a group.
        n_flows_g = group.cfg.topo.n_flows
        for fname, width, fill in (("fault_job_active", j_ref, False),
                                   ("fault_straggle", j_ref, 0.0),
                                   ("fault_blackhole", n_flows_g, False)):
            v = getattr(params, fname)
            if v is not None:
                a = _pad_cols(np.asarray(v), width, fill)
                params = params._replace(**{fname: jnp.asarray(a)})
    return params


def _stack_params(per_point: list[SweepParams]) -> SweepParams:
    out = {}
    for name in SweepParams._fields:
        vals = [getattr(p, name) for p in per_point]
        if all(v is None for v in vals):
            out[name] = None
        elif any(v is None for v in vals):
            raise ValueError(f"sweep field {name!r} set on only some points "
                             f"of one compile group")
        else:
            out[name] = jnp.stack([jnp.asarray(v) for v in vals])
    return SweepParams(**out)


def _shard_sweep(sweep: SweepParams, k: int,
                 shard) -> tuple[SweepParams, int]:
    """Optionally lay the K axis out across local devices.

    Pads K up to a multiple of the device count (repeating the last point;
    the surplus results are dropped after the run) and commits every leaf
    to a NamedSharding over a 1-D device mesh, so the jitted sweep program
    partitions the vmapped simulations across devices.  shard="auto" turns
    this on whenever more than one local device exists; single-device runs
    are returned untouched (identical jit cache keys to unsharded calls).
    """
    n_dev = jax.local_device_count()
    if shard == "auto":
        shard = n_dev > 1
    if not shard or n_dev <= 1:
        return sweep, k
    pad = (-k) % n_dev
    if pad:
        sweep = jax.tree_util.tree_map(
            lambda x: jnp.concatenate(
                [x, jnp.repeat(x[-1:], pad, axis=0)], axis=0), sweep)
    # local devices only: the pad above is computed from the local count,
    # and the sweep pytree is host-local data
    mesh = jax.sharding.Mesh(np.asarray(jax.local_devices()), ("k",))
    ns = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("k"))
    return jax.device_put(sweep, ns), k + pad


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GroupProfile:
    """Runtime profile of one compile group's sweep execution.

    Always records the end-to-end wall time and whether the call traced a
    new program (``traced``; False = served from the jit cache).  The
    trace/compile/execute split and the device-memory footprint are only
    available under ``run_plan(..., profile=True)``, which AOT-lowers the
    group (`engine.lower_sweep`) and pays a fresh XLA compile per call, so
    it is opt-in and the split fields are None otherwise.
    """

    n_points: int                     # K lowered onto the sweep axis
    n_jobs: int                       # group fabric size (padded)
    n_flows: int
    n_ticks: int                      # per simulation
    wall_s: float                     # end-to-end (trace+compile+execute)
    traced: bool
    trace_s: Optional[float] = None
    compile_s: Optional[float] = None
    execute_s: Optional[float] = None
    device_bytes: Optional[int] = None  # temp+output footprint, if exposed
    cost_envelope: Optional[dict] = None  # roofline.hlo.cost_envelope keys
    signature: Optional[str] = None     # _group_signature, for budget keys


@dataclasses.dataclass
class PlanProfile:
    """Per-group runtime profiles of one `run_plan` call.

    The costing input for scheduling follow-ons (ROADMAP: sharding *across*
    compile groups needs per-group cost estimates — this is where they come
    from).
    """

    groups: list[GroupProfile] = dataclasses.field(default_factory=list)

    @property
    def total_wall_s(self) -> float:
        return sum(g.wall_s for g in self.groups)

    @property
    def total_ticks(self) -> int:
        """Simulator ticks across every group (K * n_ticks summed)."""
        return sum(g.n_points * g.n_ticks for g in self.groups)

    def summary(self) -> dict:
        out = {"n_groups": len(self.groups),
               "wall_s": round(self.total_wall_s, 3),
               "n_traced": sum(g.traced for g in self.groups)}
        if any(g.compile_s is not None for g in self.groups):
            out["trace_s"] = round(sum(g.trace_s or 0.0
                                       for g in self.groups), 3)
            out["compile_s"] = round(sum(g.compile_s or 0.0
                                         for g in self.groups), 3)
            out["execute_s"] = round(sum(g.execute_s or 0.0
                                         for g in self.groups), 3)
        mem = [g.device_bytes for g in self.groups
               if g.device_bytes is not None]
        if mem:
            out["peak_group_device_bytes"] = max(mem)
        return out


@dataclasses.dataclass
class GroupError:
    """One compile group's failure under ``run_plan(keep_going=True)``.

    ``signature`` names the group structurally (fabric size, algorithm,
    kernel flag, dt) and ``point_labels`` carry the member points' axis
    coordinates, so a salvaged run's report says exactly which cells are
    missing and why; ``error`` is the stringified exception.
    """

    group_index: int
    signature: str
    point_labels: list[str]
    error: str


def _group_signature(group: _Group) -> str:
    c = group.cfg
    return (f"jobs={c.jobs.n_jobs} flows={c.topo.n_flows} "
            f"algo={c.protocol.cc.algo} dt={c.dt} "
            f"kernel={c.use_pallas_kernel} faults={c.faults is not None}")


@dataclasses.dataclass
class PlanResult:
    """All of a plan's results, each self-describing via its `SweepPoint`.

    Results are in plan-point order (cartesian product, last axis fastest).
    ``select`` filters by axis values *preserving that order*, so two
    selections that differ only in a scheme axis stay seed-paired for
    `sweep_speedup_stats`.

    Under ``run_plan(keep_going=True)`` a failed compile group leaves its
    members' slots as None and appends a `GroupError` to ``group_errors``;
    ``select`` / ``group_by`` skip the missing cells.
    """

    plan: Plan
    results: list[metrics.SimResult]
    n_compile_groups: int
    # jnp-oracle fallbacks of the fused CC-tick kernel traced while running
    # this plan (0 unless a config asked for use_pallas_kernel with options
    # outside the kernel's specialization — see repro.kernels.ops).  Like
    # engine.TRACE_COUNT this counts at *trace* time: a plan whose compile
    # groups are already in the jit cache reports 0 — read it off the first
    # run of a given static config.
    n_kernel_fallbacks: int = 0
    # points served from run_plan's cache_dir (0 without a cache);
    # n_compile_groups counts only the groups actually simulated.
    n_cache_hits: int = 0
    # per-group runtime profile (wall times always; the trace/compile/
    # execute split and device footprint under run_plan(..., profile=True))
    profile: PlanProfile = dataclasses.field(default_factory=PlanProfile)
    # compile groups that failed under keep_going=True (empty otherwise —
    # the default keep_going=False re-raises at the failing group)
    group_errors: list[GroupError] = dataclasses.field(default_factory=list)

    def __len__(self) -> int:
        return len(self.results)

    def __iter__(self):
        return iter(self.results)

    def __getitem__(self, i):
        return self.results[i]

    def select(self, **axis_values) -> list[metrics.SimResult]:
        """Results whose SweepPoint matches every given axis=value."""
        out = [r for r in self.results
               if r is not None and r.point.matches(**axis_values)]
        if not out:
            raise KeyError(f"no plan point matches {axis_values} "
                           f"(axes: {[a.name for a in self.plan.axes]})")
        return out

    def group_by(self, *names) -> dict[tuple, list[metrics.SimResult]]:
        """Pivot results by the given axis names -> ordered result lists."""
        out: dict[tuple, list[metrics.SimResult]] = {}
        for r in self.results:
            if r is None:
                continue
            key = tuple(r.point[n] for n in names)
            out.setdefault(key, []).append(r)
        return out

    @property
    def n_ticks(self) -> int:
        """Total simulator ticks executed (for µs/tick accounting)."""
        return sum(r.cfg.n_ticks for r in self.results if r is not None)


# ---------------------------------------------------------------------------
# On-disk point cache (resumable benchmark runs)
# ---------------------------------------------------------------------------

# Array dtype kinds the cache key encodes bit-for-bit.  Everything else —
# object arrays most importantly — is rejected loudly: ``tobytes()`` on an
# object array serializes *pointers*, which are unique per process, so a
# silently-coerced leaf would make every run a cache miss (or worse, a
# collision if the allocator reuses addresses).
_HASHABLE_KINDS = frozenset("biufcSU")  # bool/int/uint/float/complex/bytes/str


def _canonical_float_array(a: np.ndarray) -> np.ndarray:
    """Float arrays with every NaN rewritten to the canonical quiet NaN.

    IEEE NaNs carry payload/sign bits that `tobytes` would leak into the
    key: two logically-identical configs built via different code paths
    (e.g. 0/0 vs float("nan")) could hash apart and silently re-simulate.
    Distinct *positions* of NaN still produce distinct keys — only the
    bit-pattern within each NaN is normalized.
    """
    if a.dtype.kind not in "fc" or not np.isnan(a).any():
        return a
    a = a.copy()
    a[np.isnan(a)] = np.nan
    return a


def _stable_bytes(obj, out: list) -> None:
    """Deterministic byte serialization for cache keys (hash() is salted
    per process, so HashableConfig hashes cannot key an on-disk cache).

    Non-finite floats are encoded explicitly (every NaN bit-pattern maps to
    one token; +/-inf keep their signs) and array leaves must be of a
    plainly-hashable dtype — anything that numpy would coerce to an object
    array raises instead of producing a pointer-dependent key.
    """
    if obj is None or isinstance(obj, (bool, int, str)):
        out.append(repr(obj).encode())
    elif isinstance(obj, float):
        if math.isnan(obj):
            out.append(b"f:nan")
        elif math.isinf(obj):
            out.append(b"f:+inf" if obj > 0 else b"f:-inf")
        else:
            out.append(np.float64(obj).tobytes())
    elif isinstance(obj, np.ndarray):
        if obj.dtype.kind not in _HASHABLE_KINDS:
            raise TypeError(
                f"cache key leaf is a {obj.dtype} array; only "
                f"bool/int/float/complex/str arrays have a stable byte "
                f"encoding (object arrays would hash their pointers)")
        out.append(f"nd{obj.dtype}{obj.shape}".encode())
        out.append(np.ascontiguousarray(_canonical_float_array(obj))
                   .tobytes())
    elif isinstance(obj, (list, tuple)):
        out.append(f"seq{len(obj)}".encode())
        for v in obj:
            _stable_bytes(v, out)
    elif isinstance(obj, dict):
        out.append(f"map{len(obj)}".encode())
        for k in sorted(obj):
            _stable_bytes(k, out)
            _stable_bytes(obj[k], out)
    elif dataclasses.is_dataclass(obj):
        out.append(type(obj).__name__.encode())
        for f in dataclasses.fields(obj):
            _stable_bytes(f.name, out)
            _stable_bytes(getattr(obj, f.name), out)
    else:
        arr = np.asarray(obj)
        if arr.dtype.kind not in _HASHABLE_KINDS:
            raise TypeError(
                f"cache key leaf of type {type(obj).__name__} has no "
                f"stable byte encoding (coerces to a {arr.dtype} array)")
        _stable_bytes(arr, out)


# Result-schema version: bump whenever the pickled `SimResult` payload
# changes shape (new fields, changed semantics).  It salts the content hash
# AND prefixes the filename, so entries written under another schema are
# never deserialized — they simply miss — and `prune_cache` can evict them
# by name without unpickling anything.
_SCHEMA_VERSION = 2


def _point_cache_key(cfg: SimConfig, overrides: dict) -> str:
    """Content hash of everything that determines one point's result: the
    result-schema version, the point's full (uncanonicalized) config and
    its resolved dynamic overrides.  Deliberately *not* a function of the
    group the point lands in — padded lowering is value-identical to
    unpadded (DESIGN.md §5), so cached results survive regrouping (new
    axis values, pad_jobs toggles).
    """
    out: list = [f"repro-plan-cache-v{_SCHEMA_VERSION}".encode()]
    _stable_bytes(cfg, out)
    _stable_bytes({k: np.asarray(v) for k, v in overrides.items()}, out)
    return hashlib.sha256(b"".join(out)).hexdigest()[:32]


def _cache_path(cache_dir: str, key: str) -> str:
    return os.path.join(cache_dir, f"v{_SCHEMA_VERSION}-{key}.pkl")


def prune_cache(cache_dir: str) -> int:
    """Evict cache entries written under a different result-schema version.

    Stale-version entries are already unreachable (the version salts the
    key and prefixes the filename), so this only reclaims disk; returns the
    number of files removed.  Unversioned `.pkl` files (the v1 layout),
    torn `.tmp` leftovers, quarantined ``*.corrupt`` entries and zero-byte
    current-version entries (a crash between `open` and the first write of
    some other tool — `_cache_save` itself is atomic) are pruned too;
    healthy current-version entries are kept.
    """
    prefix = f"v{_SCHEMA_VERSION}-"
    removed = 0
    try:
        names = os.listdir(cache_dir)
    except OSError:
        return 0
    for name in names:
        path = os.path.join(cache_dir, name)
        stale_pkl = name.endswith(".pkl") and not name.startswith(prefix)
        zero_byte = False
        if name.endswith(".pkl") and not stale_pkl:
            try:
                zero_byte = os.path.getsize(path) == 0
            except OSError:
                pass
        if (stale_pkl or name.endswith(".tmp") or name.endswith(".corrupt")
                or zero_byte):
            try:
                os.remove(path)
                removed += 1
            except OSError:
                pass
    return removed


# Corrupt-entry paths already warned about this process (warn once per
# entry, not once per plan re-run).
_QUARANTINE_WARNED: set = set()


def _cache_load(cache_dir: str, key: str) -> Optional[metrics.SimResult]:
    path = _cache_path(cache_dir, key)
    try:
        f = open(path, "rb")
    except OSError:
        return None         # missing: a plain cache miss
    try:
        with f:
            if os.fstat(f.fileno()).st_size == 0:
                raise pickle.UnpicklingError("zero-byte cache entry")
            return pickle.load(f)
    except Exception:
        # Unreadable / truncated / schema-drifted entry: quarantine it
        # (rename to *.corrupt, so the next resume of this plan doesn't
        # trip over it again and `prune_cache` can reclaim it), warn once,
        # and treat as a miss — a corrupt entry must never crash a
        # resumable run.
        try:
            os.replace(path, path + ".corrupt")
        except OSError:
            pass
        if path not in _QUARANTINE_WARNED:
            _QUARANTINE_WARNED.add(path)
            warnings.warn(
                f"quarantined corrupt plan-cache entry {path} -> *.corrupt;"
                f" the point will be re-simulated", RuntimeWarning)
        return None


def _cache_save(cache_dir: str, key: str, res: metrics.SimResult) -> None:
    # numpy-normalize the attached params so unpickling never needs a
    # live JAX device context
    if res.point is not None and res.point.params is not None:
        res = dataclasses.replace(
            res, point=dataclasses.replace(
                res.point, params=jax.tree_util.tree_map(
                    np.asarray, res.point.params)))
    path = _cache_path(cache_dir, key)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        pickle.dump(res, f)
    os.replace(tmp, path)   # atomic: a crash never leaves a torn entry


# ---------------------------------------------------------------------------
# The runner
# ---------------------------------------------------------------------------

def _resolve_overrides(plan: Plan, points: list[dict],
                       cfgs: list[SimConfig]) -> list[dict]:
    """Each point's resolved dynamic-axis overrides ({sweep field: value}).

    A ``field="*"`` axis resolves to a dict of sweep-field overrides (or a
    callable from the point's built config to one — see `Axis`); its
    entries merge into the point's override dict like so many single-field
    axes.
    """
    dyn_axes = [ax for ax in plan.axes if ax.is_dynamic()]
    for ax in dyn_axes:
        if ax.target != "*" and ax.target not in _DYNAMIC_FIELDS:
            raise ValueError(f"axis {ax.name!r} is dynamic but targets "
                             f"unknown sweep field {ax.target!r}")
    overrides = []
    for pt, cfg in zip(points, cfgs):
        ov = {}
        for ax in dyn_axes:
            v = pt[ax.name]
            r = ax.resolve(v) if ax.resolve is not None else v
            if ax.target != "*":
                ov[ax.target] = r
                continue
            if callable(r):
                r = r(cfg)
            if not isinstance(r, dict):
                raise ValueError(
                    f"axis {ax.name!r} targets field='*' so each label "
                    f"must resolve to a dict of sweep-field overrides "
                    f"(or a callable(cfg) -> dict); "
                    f"label {pt[ax.name]!r} gave {type(r).__name__}")
            for fname, val in r.items():
                if fname not in _DYNAMIC_FIELDS:
                    raise ValueError(
                        f"axis {ax.name!r} (field='*') override names "
                        f"unknown sweep field {fname!r}")
                ov[fname] = val
        overrides.append(ov)
    return overrides


def resolve_plan(plan: Plan, *, pad_jobs: bool = True,
                 telemetry: Optional[TelemetrySpec] = None
                 ) -> tuple[list[dict], list[SimConfig], list[dict],
                            list[_Group]]:
    """The static partitioning stage of `run_plan`, without executing.

    Returns ``(points, cfgs, overrides, groups)``: the plan's label dicts,
    each point's built config (telemetry stamped on if given), its resolved
    dynamic overrides, and the predicted compile groups (each group's
    ``idxs`` index into ``points``/``cfgs``).  This is exactly the grouping
    a cache-less `run_plan` would execute — the static analyzer
    (`repro.analysis`) lints these groups' lowerings before anything runs,
    and benchmark health checks compare the prediction against what a run
    actually compiled.
    """
    points = plan.points()
    cfgs = [plan.build(dict(pt)) for pt in points]
    if telemetry is not None:
        cfgs = [dataclasses.replace(c, telemetry=telemetry) for c in cfgs]
    overrides = _resolve_overrides(plan, points, cfgs)
    groups = _compile_groups(cfgs, pad_jobs)
    return points, cfgs, overrides, groups


def group_sweep(cfgs: list[SimConfig], overrides: list[dict],
                group: _Group) -> SweepParams:
    """One compile group's batched SweepParams, exactly as `run_plan` would
    stack it (point params resolved on the group fabric, K = len(idxs))."""
    per_point = [_point_params(cfgs[i], overrides[i], group)
                 for i in group.idxs]
    return _stack_params(per_point)


def _run_group_profiled(cfg: SimConfig, sweep: SweepParams,
                        prof: GroupProfile):
    """AOT-lowered group execution with a trace/compile/execute wall-time
    split and the compiled program's device-memory footprint."""
    with counters.watch() as w:
        t0 = time.perf_counter()
        lowered = engine_mod.lower_sweep(cfg, sweep)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        raw = compiled(sweep)
        jax.block_until_ready(raw)
        t3 = time.perf_counter()
    prof.trace_s = t1 - t0
    prof.compile_s = t2 - t1
    prof.execute_s = t3 - t2
    prof.wall_s = t3 - t0
    prof.traced = w.traces > 0
    try:
        mem = compiled.memory_analysis()
        prof.device_bytes = int(mem.temp_size_in_bytes
                                + mem.output_size_in_bytes
                                + mem.argument_size_in_bytes)
    except Exception:               # backend doesn't expose the analysis
        prof.device_bytes = None
    try:
        from repro.roofline import hlo as hlo_mod
        prof.cost_envelope = hlo_mod.cost_envelope(compiled)
    except Exception:               # backend doesn't expose cost analysis
        prof.cost_envelope = None
    return raw


def run_plan(plan: Plan, *, shard="auto", pad_jobs: bool = True,
             cache_dir: Optional[str] = None,
             telemetry: Optional[TelemetrySpec] = None,
             profile: bool = False,
             keep_going: bool = False) -> PlanResult:
    """Execute a plan: one `simulate_sweep` per compile group.

    shard:     "auto" | True | False — lay each group's K axis across local
               devices (see `_shard_sweep`).
    pad_jobs:  merge workload-size variants into one padded + masked compile
               group where possible (disable to force exact grouping).
    cache_dir: if given, a directory of per-point result pickles keyed by a
               content hash of (schema version, point config, resolved
               overrides).  Points already present are served from disk and
               *excluded* from compile-group formation; fresh points are
               written back after postprocessing.  Interrupted plans resume
               where they stopped, and grown plans only simulate the new
               cells; `prune_cache` evicts entries from older schemas.
    telemetry: arm the probe subsystem (netsim.telemetry) on every point:
               the spec is stamped onto each built config (joining its
               static signature and cache key), and each `SimResult` gains
               a `.telemetry` with the probe series and detector outputs.
               None leaves the built configs untouched — a build function
               may still arm points itself.
    profile:   record a trace/compile/execute wall-time split and device
               footprint per compile group into `PlanResult.profile` via
               AOT lowering.  The AOT `.compile()` re-runs XLA on every
               call, so it is opt-in; the default path still profiles
               end-to-end wall time and whether each group (re)traced.
    keep_going: isolate per-group failures — a compile group that raises
               (bad config, OOM, compile error) is recorded on
               `PlanResult.group_errors` (its members' result slots stay
               None) and the remaining groups still run and cache, so one
               poisoned cell cannot torch a long benchmark run.  The
               default (False) re-raises at the failing group, exactly the
               pre-existing behavior.
    """
    points = plan.points()
    cfgs = [plan.build(dict(pt)) for pt in points]
    if telemetry is not None:
        cfgs = [dataclasses.replace(c, telemetry=telemetry) for c in cfgs]
    overrides = _resolve_overrides(plan, points, cfgs)

    results: list[Optional[metrics.SimResult]] = [None] * len(points)
    keys: list[Optional[str]] = [None] * len(points)
    if cache_dir is not None:
        os.makedirs(cache_dir, exist_ok=True)
        for i in range(len(points)):
            keys[i] = _point_cache_key(cfgs[i], overrides[i])
            results[i] = _cache_load(cache_dir, keys[i])
    n_cache_hits = sum(r is not None for r in results)
    todo = [i for i in range(len(points)) if results[i] is None]

    groups = _compile_groups([cfgs[i] for i in todo], pad_jobs)
    plan_profile = PlanProfile()
    group_errors: list[GroupError] = []
    with counters.watch(reset_warnings=True) as plan_watch:
        for gi, group in enumerate(groups):
            idxs = [todo[j] for j in group.idxs]  # group indexes todo subset
            try:
                per_point = [_point_params(cfgs[i], overrides[i], group)
                             for i in idxs]
                sweep = _stack_params(per_point)
                k = len(idxs)
                sweep, _ = _shard_sweep(sweep, k, shard)
                prof = GroupProfile(n_points=k, n_jobs=group.cfg.jobs.n_jobs,
                                    n_flows=group.cfg.topo.n_flows,
                                    n_ticks=group.cfg.n_ticks,
                                    wall_s=0.0, traced=False,
                                    signature=_group_signature(group))
                if profile:
                    raw = _run_group_profiled(group.cfg, sweep, prof)
                else:
                    with counters.watch() as w:
                        t0 = time.perf_counter()
                        raw = simulate_sweep(group.cfg, sweep)
                        jax.block_until_ready(raw)
                        prof.wall_s = time.perf_counter() - t0
                    prof.traced = w.traces > 0
                plan_profile.groups.append(prof)
                for slot, i in enumerate(idxs):
                    point = SweepPoint(axes=dict(points[i]),
                                       params=per_point[slot],
                                       n_jobs=cfgs[i].jobs.n_jobs)
                    raw_i = jax.tree_util.tree_map(lambda x, s=slot: x[s],
                                                   raw)
                    results[i] = metrics.postprocess(cfgs[i], raw_i,
                                                     point=point,
                                                     n_jobs=point.n_jobs)
                    if cache_dir is not None:
                        _cache_save(cache_dir, keys[i], results[i])
            except Exception as exc:
                if not keep_going:
                    raise
                group_errors.append(GroupError(
                    group_index=gi,
                    signature=_group_signature(group),
                    point_labels=[SweepPoint(axes=dict(points[i])).label()
                                  for i in idxs],
                    error=f"{type(exc).__name__}: {exc}"))
    return PlanResult(plan=plan, results=results,
                      n_compile_groups=len(groups),
                      n_kernel_fallbacks=plan_watch.fallbacks,
                      n_cache_hits=n_cache_hits,
                      profile=plan_profile,
                      group_errors=group_errors)
