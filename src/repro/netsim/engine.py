"""Fluid network simulation engine.

One `jax.lax.scan` steps the whole fabric: job phase machines, flow injection,
store-and-forward link queues with RED/ECN, RTT-delayed ack/loss/CNP feedback,
and the MLTCP-augmented congestion-control update (`repro.core.cc_tick`).

Configuration is split (DESIGN.md §3): `SimConfig` is the *static* half —
topology, job-array *shapes*, algorithm/variant choices, everything that
shapes the traced program — and `SweepParams` is the *dynamic* half:
protocol scalars (slope, intercept, g, gamma, INIT_COMM_GAP), RED
thresholds, the per-job workload values (phase programs `compute` /
`comm_bytes`, `straggle_prob`, `iso_iter`), the Static-baseline job factors,
the Cassini schedule values, the PRNG seed and the `job_active` padding
mask, carried as traced values.  `simulate_sweep` vmaps the whole chunked
scan over a leading sweep axis, so a K-point parameter / seed / workload
grid is one trace, one compile, and one device program instead of K.  The
experiment layer (`netsim.experiment`, DESIGN.md §5) lowers whole
evaluation matrices — static axes included — onto this sweep axis, one
compile group per static signature.

Model summary (hardware-adaptation notes in DESIGN.md §2):
  * fluid flows: each tick a flow injects ``min(rate*dt, bytes_left)``;
  * store-and-forward: bytes advance one link per tick; per-link service is
    ``cap*dt`` split proportionally across queued flows (FIFO-fair fluid);
  * RED at enqueue: mark/drop probability ramps linearly on queue length
    between ``red_qmin`` and ``red_qmax``; drop mode feeds Reno/CUBIC loss
    events (Bernoulli on expected dropped packets) and retransmits the bytes;
    ECN mode feeds DCQCN CNPs;
  * feedback (acks = delivered bytes, loss, CNP) returns after ``rtt`` via a
    ring buffer — the ack clock MLTCP's Algorithm 1 listens to;
  * jobs: a phase *program* (compute_s, comm_bytes) pairs per iteration —
    on/off for data-parallel jobs, multi-peak for hybrid DP/PP/TP jobs —
    with optional stragglers and Cassini-style start-time enforcement.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mltcp as core
from repro.netsim import faults as faults_mod
from repro.netsim import telemetry as telem
from repro.netsim.topology import HashableConfig, Topology

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class JobSpec(HashableConfig):
    """Per-job workload description (numpy, static).

    compute[J, P] seconds and comm_bytes[J, P] bytes define each iteration's
    sub-phase program (P >= 1; unused phases zero-padded with n_phases[J]).
    """

    compute: np.ndarray          # [J, P] seconds
    comm_bytes: np.ndarray       # [J, P] bytes
    n_phases: np.ndarray         # [J] int
    start_offset: np.ndarray     # [J] seconds
    straggle_prob: np.ndarray    # [J] probability per iteration
    iso_iter_time: np.ndarray    # [J] isolation iteration time (s)

    @staticmethod
    def simple(compute_s, comm_bytes, start_offset=None, straggle_prob=None,
               cap_bytes_per_s: float = 50e9 / 8) -> "JobSpec":
        """On/off jobs: one compute phase + one comm phase per iteration."""
        compute_s = np.asarray(compute_s, np.float64)
        comm_bytes_a = np.asarray(comm_bytes, np.float64)
        j = compute_s.shape[0]
        iso = compute_s + comm_bytes_a / cap_bytes_per_s
        return JobSpec(
            compute=compute_s[:, None],
            comm_bytes=comm_bytes_a[:, None],
            n_phases=np.ones((j,), np.int32),
            start_offset=(np.zeros((j,)) if start_offset is None
                          else np.asarray(start_offset, np.float64)),
            straggle_prob=(np.zeros((j,)) if straggle_prob is None
                           else np.asarray(straggle_prob, np.float64)),
            iso_iter_time=iso,
        )

    @property
    def n_jobs(self) -> int:
        return int(self.compute.shape[0])

    @property
    def total_bytes(self) -> np.ndarray:
        """[J] bytes per iteration (Algorithm 1's total_bytes input)."""
        return self.comm_bytes.sum(axis=1)

@dataclasses.dataclass(frozen=True, eq=False)
class CassiniSchedule(HashableConfig):
    """Centralized time-shift baseline [66]: align each job's comm-phase start
    to ``offset + k*period``; the end-host agent delays a job that deviates by
    more than ``eps`` until the next slot (which is how stragglers hurt it)."""

    offset: np.ndarray           # [J] seconds
    period: np.ndarray           # [J] seconds
    eps: float = 2e-3


@dataclasses.dataclass(frozen=True, eq=False)
class SimConfig(HashableConfig):
    topo: Topology
    jobs: JobSpec
    protocol: core.MLTCPConfig
    sim_time: float = 10.0
    dt: float = 2e-5
    # RED / buffer parameters (per link, bytes)
    red_qmin: float = 150e3
    red_qmax: float = 1.5e6
    red_pmax: float = 0.12
    buffer_bytes: float = 4e6         # taildrop ceiling
    ecn_mode: Optional[bool] = None   # default: True iff DCQCN
    # Static [67] baseline: per-JOB constant aggressiveness factors
    static_job_factors: Optional[np.ndarray] = None
    cassini: Optional[CassiniSchedule] = None
    cubic_epoch_reset_on_comm_start: bool = True
    max_iters_recorded: int = 4096
    n_chunks: int = 400               # trace resolution
    seed: int = 0
    use_pallas_kernel: bool = False   # route CC tick through kernels/ops.py
    # On-device probe subsystem (netsim.telemetry, DESIGN.md §6).  None is
    # the zero-cost default: every telemetry hook is gated on a python-level
    # `cfg.telemetry is not None`, so an unarmed config traces the exact
    # program this engine emitted before probes existed (bit-identical
    # RawSimOutput, no extra traces — pinned by tests/test_telemetry.py).
    telemetry: Optional[telem.TelemetrySpec] = None
    # Fault-injection structure (netsim.faults, DESIGN.md §8).  Like
    # `telemetry`, the spec is static (row count + armed channels shape the
    # traced program) while the schedule *values* ride in as SweepParams
    # leaves — and None is the zero-cost default: every fault hook is gated
    # on a python-level `cfg.faults is not None`, so an un-faulted config
    # traces the exact pre-fault program (bit-identical RawSimOutput,
    # pinned by tests/test_faults.py).
    faults: Optional[faults_mod.FaultSpec] = None

    @property
    def n_ticks(self) -> int:
        return int(round(self.sim_time / self.dt))

    @property
    def rtt_ticks(self) -> int:
        return max(1, int(round(self.protocol.cc.rtt / self.dt)))

    def is_ecn(self) -> bool:
        if self.ecn_mode is not None:
            return self.ecn_mode
        return self.protocol.cc.algo == int(core.Algo.DCQCN)


# ---------------------------------------------------------------------------
# Sweep axis — the dynamic (traced) half of the configuration
# ---------------------------------------------------------------------------

class SweepParams(NamedTuple):
    """Traced per-simulation parameters (one sweep grid point per entry).

    Every field the paper's evaluation sweeps — the aggressiveness function's
    slope/intercept (Fig. 16), Algorithm 1's g/gamma/INIT_COMM_GAP, the RED /
    ECN thresholds, the Static [67] per-job factors and the PRNG seed — lives
    here as a JAX value rather than a static jit argument, so
    ``simulate_sweep`` can vmap one compiled program over a whole grid.

    Unbatched (scalar) instances describe a single simulation; batched
    instances carry a leading [K] axis on every non-None leaf.

    The *workload* is traced too (the straggler / partial-compat axis):
    ``compute`` / ``comm_bytes`` are each job's per-iteration phase program,
    padded to a shared [J, P_max] shape — only ``n_phases`` (a static shape
    mask in `JobSpec`) decides which columns are live, so padding columns
    with zeros never changes a trajectory — and ``straggle_prob`` /
    ``iso_iter`` drive the per-iteration straggler sampling.  Plans that
    sweep batch size or straggle probability therefore share one compile
    group instead of compiling per workload value.

    ``job_active`` is the padded-jobs axis (DESIGN.md §5): a [J] bool mask
    that deactivates trailing jobs of an over-provisioned fabric, so a
    job-count grid (Fig. 10's 2..8 jobs) runs every point on the *largest*
    topology inside one compile group instead of one compile per count.
    Inactive jobs never start, so their flows inject nothing and are inert
    (lane-stable RNG keeps the active lanes bit-comparable to an unpadded
    run).  None means "all jobs active" and adds no masking ops.

    ``cassini_offset`` / ``cassini_period`` / ``cassini_eps`` carry the
    Cassini [66] baseline's schedule as values: a job with period <= 0 is
    simply un-scheduled, which lets Cassini and non-Cassini points of a
    plan share one compile group (the branch exists in the program, the
    per-job gate decides).  All three are None when no point needs them.

    The ``fault_*`` leaves are the fault-injection *schedule* (DESIGN.md
    §8): an event table whose row count and armed channels are fixed by
    ``cfg.faults`` (a static `FaultSpec`), whose *values* — event start
    ticks, per-event job-activity masks, link-capacity multipliers,
    blackhole masks, straggle boosts — are traced, so a churn grid
    (schedule x seed x variant) shares one compile group.  All None when
    ``cfg.faults`` is None; `faults.identity_schedule` gives exact-no-op
    values for an armed spec.
    """

    slope: Array                # F(x) = slope * x + intercept      (Eq. 3)
    intercept: Array
    g: Array                    # Algorithm 1 noise tolerance
    gamma: Array                # Algorithm 1 iter_gap EWMA factor
    init_comm_gap: Array        # Algorithm 1 INIT_COMM_GAP (s)
    red_qmin: Array             # RED ramp start (bytes)
    red_qmax: Array             # RED ramp knee (bytes)
    red_pmax: Array             # RED mark/drop probability at the knee
    seed: Array                 # int32 PRNG seed
    compute: Array              # [J, P] per-phase compute seconds
    comm_bytes: Array           # [J, P] per-phase comm bytes
    straggle_prob: Array        # [J] straggle probability per iteration
    iso_iter: Array             # [J] isolation iteration time (s)
    static_job_factors: Optional[Array]  # [J] Static-baseline factors or None
    job_active: Optional[Array] = None   # [J] bool mask (padded-jobs axis)
    cassini_offset: Optional[Array] = None  # [J] slot-grid offsets (s)
    cassini_period: Optional[Array] = None  # [J] slot periods; <=0 = off
    cassini_eps: Optional[Array] = None     # scalar agent tolerance (s)
    fault_tick: Optional[Array] = None        # [E] int32 event start ticks
    fault_job_active: Optional[Array] = None  # [E, J] bool churn masks
    fault_link_scale: Optional[Array] = None  # [E, M] capacity multipliers
    fault_blackhole: Optional[Array] = None   # [E, N] bool null-route masks
    fault_straggle: Optional[Array] = None    # [E, J] straggle-prob boosts

    def dyn(self) -> core.DynamicParams:
        """The protocol-layer slice, for `core.cc_tick`."""
        return core.DynamicParams(slope=self.slope, intercept=self.intercept,
                                  g=self.g, gamma=self.gamma,
                                  init_comm_gap=self.init_comm_gap)


# Per-sweep-point shapes/dtypes: most fields are scalars; the per-job
# fields carry a [J] axis per point ([K, J] batched) and the phase
# programs a [J, P] axis pair ([K, J, P] batched).
_POINT_NDIM = {
    "static_job_factors": 1, "job_active": 1,
    "compute": 2, "comm_bytes": 2,
    "straggle_prob": 1, "iso_iter": 1,
    "cassini_offset": 1, "cassini_period": 1,
    "fault_tick": 1, "fault_job_active": 2, "fault_link_scale": 2,
    "fault_blackhole": 2, "fault_straggle": 2,
}
_FIELD_DTYPE = {"seed": jnp.int32, "job_active": jnp.bool_,
                "fault_tick": jnp.int32, "fault_job_active": jnp.bool_,
                "fault_blackhole": jnp.bool_}


def _point_shape(name: str, cfg: SimConfig) -> tuple[int, ...]:
    """The per-point (unbatched) shape of a sweep field on cfg's fabric."""
    if name.startswith("fault_"):
        if cfg.faults is None:
            raise ValueError(
                f"sweep field {name!r} needs cfg.faults (a FaultSpec) — "
                f"fault schedule values have no meaning on an un-faulted "
                f"config")
        e = cfg.faults.n_events
        if name == "fault_tick":
            return (e,)
        if name == "fault_link_scale":
            return (e, cfg.topo.n_links)
        if name == "fault_blackhole":
            return (e, cfg.topo.n_flows)
        return (e, cfg.jobs.n_jobs)       # fault_job_active / fault_straggle
    nd = _POINT_NDIM.get(name, 0)
    if nd == 0:
        return ()
    j, p = cfg.jobs.compute.shape
    return (j,) if nd == 1 else (j, p)


def _unknown_field_error(name: str) -> ValueError:
    return ValueError(
        f"unknown sweep field {name!r}: not a SweepParams leaf "
        f"(it would silently compile per-point instead of riding the "
        f"batched sweep); valid leaves: {', '.join(SweepParams._fields)}")


def sweep_of(cfg: SimConfig) -> SweepParams:
    """Lift a config's dynamic values into an (unbatched) SweepParams."""
    sf = None
    if cfg.static_job_factors is not None:
        sf = jnp.asarray(np.asarray(cfg.static_job_factors), jnp.float32)
    cas_off = cas_per = cas_eps = None
    if cfg.cassini is not None:
        cas_off = jnp.asarray(cfg.cassini.offset, jnp.float32)
        cas_per = jnp.asarray(cfg.cassini.period, jnp.float32)
        cas_eps = jnp.asarray(cfg.cassini.eps, jnp.float32)
    # an armed FaultSpec defaults to the identity schedule (exact no-op
    # values); real schedules arrive as make_sweep overrides
    fault_vals = {name: None for name in faults_mod.FIELDS}
    if cfg.faults is not None:
        ident = faults_mod.identity_schedule(cfg, cfg.faults).values
        for name, v in ident.items():
            fault_vals[name] = jnp.asarray(
                v, _FIELD_DTYPE.get(name, jnp.float32))
    p = cfg.protocol
    jobs = cfg.jobs
    return SweepParams(
        slope=jnp.asarray(p.slope, jnp.float32),
        intercept=jnp.asarray(p.intercept, jnp.float32),
        g=jnp.asarray(p.g, jnp.float32),
        gamma=jnp.asarray(p.gamma, jnp.float32),
        init_comm_gap=jnp.asarray(p.init_comm_gap, jnp.float32),
        red_qmin=jnp.asarray(cfg.red_qmin, jnp.float32),
        red_qmax=jnp.asarray(cfg.red_qmax, jnp.float32),
        red_pmax=jnp.asarray(cfg.red_pmax, jnp.float32),
        seed=jnp.asarray(cfg.seed, jnp.int32),
        compute=jnp.asarray(jobs.compute, jnp.float32),
        comm_bytes=jnp.asarray(jobs.comm_bytes, jnp.float32),
        straggle_prob=jnp.asarray(jobs.straggle_prob, jnp.float32),
        iso_iter=jnp.asarray(jobs.iso_iter_time, jnp.float32),
        static_job_factors=sf,
        cassini_offset=cas_off,
        cassini_period=cas_per,
        cassini_eps=cas_eps,
        **fault_vals,
    )


def make_sweep(cfg: SimConfig, **overrides) -> SweepParams:
    """Build a batched SweepParams from a config plus per-field overrides.

    Each override is a scalar (held constant — per-job fields broadcast it
    across the point shape) or a length-K sequence (the sweep values); the
    per-job fields (``straggle_prob``, ``iso_iter``, ``job_active``,
    ``static_job_factors``, ``cassini_*``) also take [J] or [K, J], and the
    phase programs (``compute``, ``comm_bytes``) take [J, P] or [K, J, P].
    All length-K overrides must agree on K; unswept fields are broadcast
    from the config.
    """
    base = sweep_of(cfg)
    lens = []
    for name, v in overrides.items():
        if name not in SweepParams._fields:
            raise _unknown_field_error(name)
        nd = _POINT_NDIM.get(name, 0)
        a = np.asarray(v)
        if a.ndim == nd + 1:
            lens.append(a.shape[0])
        elif a.ndim not in (0, nd):
            raise ValueError(
                f"sweep field {name!r} has shape {a.shape}; expected a "
                f"scalar, the point shape {_point_shape(name, cfg)}, or a "
                f"[K]-leading batch of point shapes")
    k = lens[0] if lens else 1
    if any(l != k for l in lens):
        raise ValueError(f"sweep fields disagree on length: {lens}")
    out = {}
    for name in SweepParams._fields:
        v = overrides.get(name, getattr(base, name))
        if v is None:
            out[name] = None
            continue
        a = jnp.asarray(v, _FIELD_DTYPE.get(name, jnp.float32))
        nd = _POINT_NDIM.get(name, 0)
        if a.ndim == 0 and nd > 0:
            a = jnp.broadcast_to(a, _point_shape(name, cfg))
        if a.ndim == nd:
            a = jnp.broadcast_to(a[None], (k,) + a.shape)
        out[name] = a
    return SweepParams(**out)


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """Self-describing label for one grid point of a sweep/plan.

    ``axes`` maps axis name -> that point's value (the *label* the caller
    enumerated — e.g. ``{"slope": 1.75, "seed": 2}`` or
    ``{"variant": "WI", "n_jobs": 4}``); ``params`` is the resolved
    unbatched SweepParams actually run, so results carry both the
    human-facing coordinates and the exact dynamic values.  ``n_jobs`` is
    the point's *active* job count on a padded fabric (None: all jobs).

    Travels with its `SimResult` (``metrics.postprocess(..., point=...)``),
    so aggregation never relies on positional alignment with a label list.
    """

    axes: dict
    params: Optional[SweepParams] = None
    n_jobs: Optional[int] = None

    def __getitem__(self, name: str):
        return self.axes[name]

    def get(self, name: str, default=None):
        return self.axes.get(name, default)

    def matches(self, **axis_values) -> bool:
        """True iff every given axis name exists and equals the value."""
        for name, want in axis_values.items():
            if name not in self.axes:
                return False
            have = self.axes[name]
            if isinstance(have, np.ndarray) or isinstance(want, np.ndarray):
                if not np.array_equal(np.asarray(have), np.asarray(want)):
                    return False
            elif have != want:
                return False
        return True

    def label(self) -> str:
        return ",".join(f"{k}={v}" for k, v in self.axes.items())


def sweep_slice(sweep: SweepParams, i: int) -> SweepParams:
    """The i-th unbatched point of a batched SweepParams."""
    return jax.tree_util.tree_map(lambda x: x[i], sweep)


def grid_sweep(cfg: SimConfig, **axes) -> tuple[SweepParams, list[SweepPoint]]:
    """Cartesian-product sweep over the given scalar axes.

    Returns the batched SweepParams (K = product of axis lengths) plus one
    `SweepPoint` per grid point carrying that point's axis values *and* its
    resolved params, so labels round-trip through
    `metrics.postprocess_sweep(cfg, raw, points)` attached to each result
    instead of relying on positional alignment.
    """
    names = list(axes)
    for n in names:
        if n not in SweepParams._fields:
            raise _unknown_field_error(n)
    grids = np.meshgrid(*[np.asarray(axes[n], np.float64) for n in names],
                        indexing="ij")
    flat = {n: g.reshape(-1) for n, g in zip(names, grids)}
    # per-job / per-phase fields: each scalar axis label broadcasts to the
    # point shape, so e.g. straggle_prob=[0.0, 0.1] sweeps a uniform
    # probability across jobs ([K] labels -> [K, J] values)
    values = {}
    for n in names:
        nd = _POINT_NDIM.get(n, 0)
        v = flat[n]
        if nd:
            pshape = _point_shape(n, cfg)
            v = np.broadcast_to(v.reshape((-1,) + (1,) * nd),
                                (v.shape[0],) + pshape)
        values[n] = v
    sweep = make_sweep(cfg, **values)
    n_jobs = cfg.jobs.n_jobs
    k = sweep_len(sweep)
    points = [SweepPoint(axes={n: flat[n][i].item() for n in names},
                         params=sweep_slice(sweep, i), n_jobs=n_jobs)
              for i in range(k)] if names else \
        [SweepPoint(axes={}, params=sweep_slice(sweep, 0), n_jobs=n_jobs)]
    return sweep, points


def sweep_len(sweep: SweepParams) -> int:
    """K, the number of grid points in a batched SweepParams."""
    return int(sweep.slope.shape[0])


# ---------------------------------------------------------------------------
# Engine state
# ---------------------------------------------------------------------------

class EngineState(NamedTuple):
    proto: core.MLTCPState
    backlog: Array        # [M+1, N] queued bytes (row M = trash)
    transit: Array        # [M+1, N] bytes arriving next tick
    ring_del: Array       # [D, N] delivered bytes (feedback delay line)
    ring_loss: Array      # [D, N] bool
    ring_cnp: Array       # [D, N] bool
    ring_ptr: Array       # int32
    to_send: Array        # [N] bytes not yet injected (this comm sub-phase)
    to_deliver: Array     # [N] bytes not yet delivered
    comm_start: Array     # [N] time current comm sub-phase started
    phase_idx: Array      # [J]
    in_comm: Array        # [J] bool
    t_rem: Array          # [J] remaining compute seconds
    iter_idx: Array       # [J]
    iter_start: Array     # [J]
    hold_until: Array     # [J]
    iter_times: Array     # [J, MAX_ITERS]
    straggle_extra: Array # [J] sampled straggle time for current iteration
    key: Array
    tick: Array           # int32
    # accumulators for trace chunks
    acc_util: Array       # [M]
    acc_drops: Array      # scalar (packets)
    acc_marks: Array      # scalar (packets)
    acc_jobbytes: Array   # [J] delivered bytes per job
    # armed-probe ring buffers + detector state; None (zero pytree leaves)
    # unless cfg.telemetry arms the subsystem
    telemetry: Optional[telem.TelemetryState] = None


class TickStatics(NamedTuple):
    """Device-resident static arrays used by the tick function.

    Only *structural* data lives here — routing, fan-out, phase counts,
    start offsets.  The workload values (phase programs, straggle
    probabilities, Cassini schedules) are traced `SweepParams` leaves and
    the per-job totals derived from them (`_workload_view`) are computed
    per sweep point.
    """

    cap: Array            # [M]
    first_link: Array     # [N]
    next_link: Array      # [M+1, N] (M = trash/delivered)
    f2j: Array            # [N]
    spj_inv: Array        # [N] 1/flows-in-job
    n_phases: Array       # [J]
    start_offset: Array   # [J]


def _build_statics(cfg: SimConfig) -> TickStatics:
    topo, jobs = cfg.topo, cfg.jobs
    M, N = topo.n_links, topo.n_flows
    hops = topo.hops
    first_link = hops[:, 0].astype(np.int32)
    # next_link[l, n]: link after l on n's path; M (trash) means "delivered".
    nxt = np.full((M + 1, N), M, np.int32)
    for n in range(N):
        path = [l for l in hops[n] if l >= 0]
        for i, l in enumerate(path):
            nxt[l, n] = path[i + 1] if i + 1 < len(path) else M
    f2j = topo.flow_to_job.astype(np.int32)
    spj = np.bincount(f2j, minlength=jobs.n_jobs).astype(np.float64)
    return TickStatics(
        cap=jnp.asarray(topo.cap, jnp.float32),
        first_link=jnp.asarray(first_link),
        next_link=jnp.asarray(nxt),
        f2j=jnp.asarray(f2j),
        spj_inv=jnp.asarray(1.0 / spj[f2j], jnp.float32),
        n_phases=jnp.asarray(jobs.n_phases, jnp.int32),
        start_offset=jnp.asarray(jobs.start_offset, jnp.float32),
    )


class _WorkloadView(NamedTuple):
    """Per-point values derived from the traced workload leaves."""

    job_total_bytes: Array  # [J] bytes per iteration (Algorithm 1 input)
    period: Array           # [J] nominal iteration period (normalizer)


def _workload_view(cfg: SimConfig, sweep: SweepParams) -> _WorkloadView:
    total = sweep.comm_bytes.sum(axis=-1)
    # 1/cap.min() folds to a python float so the division-by-constant is a
    # reciprocal multiply in every program that computes it (bit-equality
    # between compile groups; DESIGN.md §4)
    inv_cap = float(1.0 / np.asarray(cfg.topo.cap, np.float64).min())
    period = sweep.compute.sum(axis=-1) + total * jnp.float32(inv_cap)
    return _WorkloadView(job_total_bytes=total, period=period)


def _init_state(cfg: SimConfig, statics: TickStatics,
                sweep: SweepParams) -> EngineState:
    topo, jobs = cfg.topo, cfg.jobs
    M, N, J = topo.n_links, topo.n_flows, jobs.n_jobs
    D = cfg.rtt_ticks
    z = jnp.zeros
    return EngineState(
        proto=core.init_state(N, cfg.protocol, dyn=sweep.dyn()),
        backlog=z((M + 1, N), jnp.float32),
        transit=z((M + 1, N), jnp.float32),
        ring_del=z((D, N), jnp.float32),
        ring_loss=z((D, N), bool),
        ring_cnp=z((D, N), bool),
        ring_ptr=jnp.asarray(0, jnp.int32),
        to_send=z((N,), jnp.float32),
        to_deliver=z((N,), jnp.float32),
        comm_start=z((N,), jnp.float32),
        phase_idx=z((J,), jnp.int32),
        in_comm=z((J,), bool),
        t_rem=sweep.compute[:, 0],            # start in compute of phase 0
        iter_idx=z((J,), jnp.int32),
        iter_start=statics.start_offset,
        hold_until=z((J,), jnp.float32),
        iter_times=jnp.full((J, cfg.max_iters_recorded), jnp.nan, jnp.float32),
        straggle_extra=z((J,), jnp.float32),
        key=jax.random.PRNGKey(sweep.seed),
        tick=jnp.asarray(0, jnp.int32),
        acc_util=z((M,), jnp.float32),
        acc_drops=jnp.asarray(0.0, jnp.float32),
        acc_marks=jnp.asarray(0.0, jnp.float32),
        acc_jobbytes=z((J,), jnp.float32),
        telemetry=(telem.init_state(cfg, cfg.telemetry)
                   if cfg.telemetry is not None else None),
    )


# ---------------------------------------------------------------------------
# One tick
# ---------------------------------------------------------------------------

def _mix32(x: Array) -> Array:
    """murmur3's 32-bit finalizer — a cheap full-avalanche bijection."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _lane_uniform(key: Array, n: int) -> Array:
    """Per-lane U[0,1) draws where lane i depends only on (key, i).

    `jax.random.uniform(key, (n,))` has *no* prefix property — its counter
    layout depends on n, so a padded fabric would draw different randomness
    than an unpadded one.  Hashing (key, lane index) counter-style instead
    makes the first n lanes of a padded run bit-identical to an unpadded
    run, which is what lets the padded-jobs axis (`SweepParams.job_active`)
    share one compile group across job counts without changing any
    trajectory.  Two keyed murmur3 finalizer rounds stay ~10 ALU ops per
    lane — a per-lane `jax.random.fold_in` costs a threefry hash each and
    ~3x the whole engine's tick rate.
    """
    lanes = jnp.arange(n, dtype=jnp.uint32)
    h = _mix32(lanes ^ key[0].astype(jnp.uint32))
    h = _mix32(h ^ key[1].astype(jnp.uint32))
    # top 24 bits -> [0, 1) at float32 resolution
    return (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1 / (1 << 24))


def _red_prob(sweep: SweepParams, q: Array) -> Array:
    """Gentle RED: 0 -> pmax on [qmin, qmax], pmax -> 1 on [qmax, 2*qmax]."""
    ramp1 = jnp.clip((q - sweep.red_qmin)
                     / (sweep.red_qmax - sweep.red_qmin),
                     0.0, 1.0) * sweep.red_pmax
    ramp2 = jnp.clip((q - sweep.red_qmax) / sweep.red_qmax, 0.0, 1.0) \
        * (1.0 - sweep.red_pmax)
    return ramp1 + ramp2


def _tick(cfg: SimConfig, statics: TickStatics, sweep: SweepParams,
          wl: _WorkloadView, st: EngineState,
          _unused) -> tuple[EngineState, None]:
    dt = jnp.float32(cfg.dt)
    t = st.tick.astype(jnp.float32) * dt
    M = cfg.topo.n_links
    N = cfg.topo.n_flows
    J = cfg.jobs.n_jobs
    mss = cfg.protocol.cc.mss
    arange_n = jnp.arange(N)

    key, k_loss, k_cnp, k_strag, k_samt = jax.random.split(st.key, 5)

    # ------------------------------------------------------------------
    # 0. Fault-event gather (cfg.faults is None -> this block vanishes)
    # ------------------------------------------------------------------
    fault_idx = None
    if cfg.faults is not None:
        # event rows are sorted by start tick; row e is in effect on
        # [fault_tick[e], fault_tick[e+1]) and row 0 is the identity
        # baseline at tick 0, so the current row is a rank over the tick
        # column — one reduce + gather per tick, no control flow, and
        # nothing reaches the CC-tick kernel (DESIGN.md §8)
        fault_idx = jnp.clip(
            jnp.sum((sweep.fault_tick <= st.tick).astype(jnp.int32)) - 1,
            0, cfg.faults.n_events - 1)

    # ------------------------------------------------------------------
    # 1. Job phase machine: compute countdown -> comm-phase entry
    # ------------------------------------------------------------------
    started = t >= statics.start_offset
    if sweep.job_active is not None:
        # padded-jobs axis: masked-off jobs never start, so their flows
        # stay inert (no injection, no iterations) for this sweep point
        started = started & sweep.job_active
    churn_row = None
    if cfg.faults is not None and cfg.faults.churn:
        # churn: a departed job's compute clock freezes (`started` gate)
        # and its comm phase is force-exited below, so its flows stop
        # injecting; on re-arrival the stale t_rem <= 0 re-enters the
        # interrupted comm sub-phase with a fresh quota.  The identity
        # row is all-True — `& True` is an exact no-op.
        churn_row = sweep.fault_job_active[fault_idx]            # [J]
        started = started & churn_row
    t_rem = jnp.where(~st.in_comm & started, st.t_rem - dt, st.t_rem)
    compute_done = ~st.in_comm & started & (t_rem <= 0.0)

    if sweep.cassini_period is not None:
        # Cassini agent: comm may only start on its slot grid (+/- eps).
        # The schedule is a traced per-job value; period <= 0 disables the
        # agent for that job (value-identical to the no-Cassini program),
        # so scheduled and unscheduled plan points share one compile group.
        on = sweep.cassini_period > 0.0
        per = jnp.maximum(sweep.cassini_period, 1e-6)
        k = jnp.ceil((t - sweep.cassini_offset) / per)
        next_slot = sweep.cassini_offset + k * per
        near = jnp.abs(jnp.round((t - sweep.cassini_offset) / per) * per
                       + sweep.cassini_offset - t) <= sweep.cassini_eps
        hold = jnp.where(compute_done & on & ~near & (st.hold_until <= t),
                         next_slot, st.hold_until)
        enter_comm = compute_done & (~on | near | (t >= hold))
        hold_until = hold
    else:
        enter_comm = compute_done
        hold_until = st.hold_until

    in_comm = st.in_comm | enter_comm
    if churn_row is not None:
        in_comm = in_comm & churn_row

    # flows of entering jobs pick up their sub-phase quota
    phase_bytes_job = sweep.comm_bytes[jnp.arange(J), st.phase_idx]  # [J]
    enter_f = enter_comm[statics.f2j]
    quota_f = (phase_bytes_job[statics.f2j] * statics.spj_inv)
    to_send = jnp.where(enter_f, quota_f, st.to_send)
    to_deliver = jnp.where(enter_f, quota_f, st.to_deliver)
    comm_start = jnp.where(enter_f, t, st.comm_start)

    # ------------------------------------------------------------------
    # 2. Injection at current CC rate
    # ------------------------------------------------------------------
    rate = core.send_rate(cfg.protocol.cc, st.proto.cc)          # [N] bytes/s
    active = in_comm[statics.f2j] & (to_send > 0.0)
    inj = jnp.where(active, jnp.minimum(rate * dt, to_send), 0.0)
    to_send = to_send - inj
    inj_lost = None
    if cfg.faults is not None and cfg.faults.blackholes:
        # blackholed flows are null-routed at the first hop: injected
        # bytes vanish as drops (folded into dropped_f below, so they
        # loss-signal after the usual feedback delay and retransmit when
        # the hole closes).  Identity row is all-False: inj - 0.0 exact.
        bh_row = sweep.fault_blackhole[fault_idx]                # [N]
        inj_lost = jnp.where(bh_row, inj, 0.0)
        inj = inj - inj_lost

    # ------------------------------------------------------------------
    # 3. Links: enqueue (RED) -> serve -> route departures
    # ------------------------------------------------------------------
    incoming = st.transit
    incoming = incoming.at[statics.first_link, arange_n].add(inj)
    incoming = incoming.at[M].set(0.0)                           # trash row

    q_len = st.backlog[:M].sum(axis=1)                           # [M]
    p_red = _red_prob(sweep, q_len)                              # [M]
    p_full = jnp.concatenate([p_red, jnp.zeros((1,), p_red.dtype)])
    # taildrop on buffer overflow (both modes)
    overflow = jnp.concatenate([
        (q_len >= cfg.buffer_bytes).astype(jnp.float32), jnp.zeros((1,))])

    if cfg.is_ecn():
        marked = incoming * p_full[:, None]
        drop_frac = overflow[:, None]
    else:
        marked = jnp.zeros_like(incoming)
        drop_frac = jnp.minimum(p_full[:, None] + overflow[:, None], 1.0)

    dropped = incoming * drop_frac
    kept = incoming - dropped
    backlog = st.backlog + kept

    tot = backlog[:M].sum(axis=1)
    cap_eff = statics.cap
    if cfg.faults is not None and cfg.faults.link_flaps:
        # link flaps scale the *service* capacity only; acc_util keeps the
        # nominal cap as its normalizer (utilization stays comparable
        # across the flap, and scale=0.0 never divides by zero).  The
        # identity row is all-ones: cap * 1.0 is bit-exact.
        cap_eff = cap_eff * sweep.fault_link_scale[fault_idx]    # [M]
    serve_ratio = jnp.where(tot > 0.0,
                            jnp.minimum(1.0, cap_eff * dt / jnp.maximum(tot, 1e-9)),
                            0.0)
    serve_full = jnp.concatenate([serve_ratio, jnp.zeros((1,))])
    dep = backlog * serve_full[:, None]
    backlog = backlog - dep
    backlog = backlog.at[M].set(0.0)

    # route departures: next_link == M means delivered
    is_final = statics.next_link == M                            # [M+1, N]
    delivered = jnp.sum(dep * is_final, axis=0)                  # [N]
    fwd = dep * (~is_final)
    transit = jnp.zeros_like(st.transit).at[
        statics.next_link.reshape(-1), jnp.tile(arange_n, M + 1)
    ].add(fwd.reshape(-1))
    transit = transit.at[M].set(0.0)

    # per-flow drop / mark signals
    dropped_f = dropped.sum(axis=0)                              # [N] bytes
    if inj_lost is not None:
        dropped_f = dropped_f + inj_lost       # blackholed first-hop bytes
    marked_f = marked.sum(axis=0)
    loss_evt = _lane_uniform(k_loss, N) < -jnp.expm1(-dropped_f / mss)
    cnp_evt = _lane_uniform(k_cnp, N) < -jnp.expm1(-marked_f / mss)
    # dropped bytes must be retransmitted
    to_send = to_send + dropped_f

    # ------------------------------------------------------------------
    # 4. Feedback delay line (acks/loss/CNP arrive one RTT later)
    # ------------------------------------------------------------------
    ptr = st.ring_ptr
    fb_del = st.ring_del[ptr]
    fb_loss = st.ring_loss[ptr]
    fb_cnp = st.ring_cnp[ptr]
    ring_del = st.ring_del.at[ptr].set(delivered)
    ring_loss = st.ring_loss.at[ptr].set(loss_evt)
    ring_cnp = st.ring_cnp.at[ptr].set(cnp_evt)
    ring_ptr = (ptr + 1) % cfg.rtt_ticks

    # ------------------------------------------------------------------
    # 5. Byte accounting & comm-phase completion
    # ------------------------------------------------------------------
    to_deliver = jnp.maximum(to_deliver - delivered, 0.0)
    flow_done = (to_deliver <= 0.5 * mss).astype(jnp.int32)
    job_all_done = jnp.ones((J,), jnp.int32).at[statics.f2j].min(flow_done) > 0
    comm_done = in_comm & job_all_done

    last_phase = st.phase_idx >= (statics.n_phases - 1)
    iter_done = comm_done & last_phase
    phase_idx = jnp.where(comm_done, jnp.where(last_phase, 0, st.phase_idx + 1),
                          st.phase_idx)
    in_comm = in_comm & ~comm_done

    # iteration bookkeeping + straggler sampling for the next iteration
    iter_time = t - st.iter_start
    iter_times = st.iter_times.at[
        jnp.arange(J), jnp.minimum(st.iter_idx, cfg.max_iters_recorded - 1)
    ].set(jnp.where(iter_done, iter_time,
                    st.iter_times[jnp.arange(J),
                                  jnp.minimum(st.iter_idx,
                                              cfg.max_iters_recorded - 1)]))
    iter_idx = st.iter_idx + iter_done.astype(jnp.int32)
    iter_start = jnp.where(iter_done, t, st.iter_start)

    strag_p = sweep.straggle_prob
    if cfg.faults is not None and cfg.faults.straggle_bursts:
        # additive boost, clipped back to a probability; identity row is
        # all-zeros (p + 0.0 and clip-to-[0,1] of a probability are exact)
        strag_p = jnp.clip(strag_p + sweep.fault_straggle[fault_idx],
                           0.0, 1.0)
    straggles = _lane_uniform(k_strag, J) < strag_p
    strag_amt = (0.05 + 0.05 * _lane_uniform(k_samt, J)) * sweep.iso_iter
    straggle_extra = jnp.where(iter_done,
                               jnp.where(straggles, strag_amt, 0.0),
                               st.straggle_extra)

    next_compute = sweep.compute[jnp.arange(J), phase_idx]
    t_rem = jnp.where(comm_done,
                      next_compute + jnp.where(iter_done, straggle_extra, 0.0),
                      t_rem)

    # ------------------------------------------------------------------
    # 6. Protocol update (MLTCP / baselines) on delayed feedback
    # ------------------------------------------------------------------
    fb = core.Feedback(num_acks=fb_del / mss, loss=fb_loss, cnp=fb_cnp, now=t)
    flow_total = jnp.where(
        jnp.asarray(cfg.protocol.aggregate_by_job),
        wl.job_total_bytes[statics.f2j],
        wl.job_total_bytes[statics.f2j] * statics.spj_inv)
    comm_elapsed = jnp.clip((t - comm_start) / wl.period[statics.f2j],
                            0.0, 1.0)
    est_finish = jnp.clip(to_deliver / jnp.maximum(rate, 1.0)
                          / wl.period[statics.f2j], 0.0, 1.0)

    # the kernel path takes the same traced DynamicParams as the oracle:
    # protocol scalars are operands of the fused kernel (DESIGN.md §4), so
    # K=1 and K>1 sweeps share this one dispatch
    tick_fn = core.cc_tick
    dyn = sweep.dyn()
    if cfg.use_pallas_kernel:
        from repro.kernels import ops as kernel_ops
        tick_fn = kernel_ops.mltcp_cc_tick
    static_factors = (sweep.static_job_factors[statics.f2j]
                      if sweep.static_job_factors is not None else None)
    proto, _ = tick_fn(
        cfg.protocol, st.proto, fb, flow_total,
        flow_to_job=statics.f2j, n_jobs=J,
        static_factors=static_factors,
        comm_elapsed=comm_elapsed, est_finish=est_finish,
        dyn=dyn)

    # CUBIC epoch reset on comm start (idle handling; see DESIGN.md)
    if (cfg.cubic_epoch_reset_on_comm_start
            and cfg.protocol.cc.algo == int(core.Algo.CUBIC)):
        cc = proto.cc._replace(
            epoch_start=jnp.where(enter_f, t, proto.cc.epoch_start),
            w_max=jnp.where(enter_f, proto.cc.cwnd, proto.cc.w_max))
        proto = proto._replace(cc=cc)

    # ------------------------------------------------------------------
    # 7. Trace accumulators
    # ------------------------------------------------------------------
    acc_util = st.acc_util + dep[:M].sum(axis=1) / (statics.cap * dt)
    acc_drops = st.acc_drops + dropped_f.sum() / mss
    acc_marks = st.acc_marks + marked_f.sum() / mss
    acc_jobbytes = st.acc_jobbytes.at[statics.f2j].add(delivered)

    # ------------------------------------------------------------------
    # 8. Telemetry probes + streaming detectors (off = this block vanishes)
    # ------------------------------------------------------------------
    tstate = st.telemetry
    if cfg.telemetry is not None:
        spec = cfg.telemetry
        f_job = None
        if spec.wants("job_f"):
            # recompute the factor stage from the post-update detection
            # state (the kernel path doesn't return per-flow F), then
            # average socket factors per job
            f_flow = core.f_values(cfg.protocol, proto.det, fb,
                                   comm_elapsed, est_finish, dyn,
                                   static_factors=static_factors)
            f_job = (jnp.zeros((J,), jnp.float32).at[statics.f2j]
                     .add(f_flow * statics.spj_inv))
        # a churn-departed job leaves the interleave statistic exactly like
        # a padded-out job: fold the current churn row into the activity
        # mask (identity row is all-True -> an exact no-op `&`)
        telem_active = sweep.job_active
        if churn_row is not None:
            telem_active = (churn_row if telem_active is None
                            else telem_active & churn_row)
        sig = telem.TickSignals(
            tick=st.tick, t=t,
            cwnd=proto.cc.cwnd, rate=rate,
            bytes_ratio=proto.det.bytes_ratio,
            q_len=q_len, red_prob=p_red,
            in_comm=in_comm, phase_idx=phase_idx, iter_idx=iter_idx,
            iter_done=iter_done, iter_time=iter_time,
            f_job=f_job, job_active=telem_active,
            fault_idx=fault_idx,
            fault_ticks=(sweep.fault_tick if cfg.faults is not None
                         else None))
        tstate = telem.tick_update(cfg, spec, st.telemetry, sig)

    return EngineState(
        proto=proto, backlog=backlog, transit=transit,
        ring_del=ring_del, ring_loss=ring_loss, ring_cnp=ring_cnp,
        ring_ptr=ring_ptr,
        to_send=to_send, to_deliver=to_deliver, comm_start=comm_start,
        phase_idx=phase_idx, in_comm=in_comm, t_rem=t_rem,
        iter_idx=iter_idx, iter_start=iter_start, hold_until=hold_until,
        iter_times=iter_times, straggle_extra=straggle_extra,
        key=key, tick=st.tick + 1,
        acc_util=acc_util, acc_drops=acc_drops, acc_marks=acc_marks,
        acc_jobbytes=acc_jobbytes, telemetry=tstate,
    ), None


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

class RawSimOutput(NamedTuple):
    iter_times: Array     # [J, MAX_ITERS] seconds (nan where unset)
    iter_counts: Array    # [J]
    trace_util: Array     # [n_chunks, M] mean utilization per chunk
    trace_drops: Array    # [n_chunks] packets per chunk
    trace_marks: Array    # [n_chunks]
    trace_incomm: Array   # [n_chunks, J] bool snapshot
    trace_t: Array        # [n_chunks] chunk end times
    trace_jobtput: Array  # [n_chunks, J] delivered bytes/s per job
    trace_ratio: Array    # [n_chunks, J] mean bytes_ratio snapshot per job
    final_state: EngineState
    # final TelemetryState (ring buffers + detector scalars) when
    # cfg.telemetry is armed; None (zero extra leaves) otherwise
    telemetry: Optional[telem.TelemetryState] = None


def _run_single(cfg: SimConfig, statics: TickStatics,
                sweep: SweepParams) -> RawSimOutput:
    """One simulation as a pure traced function of an unbatched sweep point."""
    st = _init_state(cfg, statics, sweep)
    ticks_per_chunk = max(1, cfg.n_ticks // cfg.n_chunks)
    n_chunks = cfg.n_ticks // ticks_per_chunk
    tick = partial(_tick, cfg, statics, sweep, _workload_view(cfg, sweep))

    def chunk(st: EngineState, _):
        st = st._replace(acc_util=jnp.zeros_like(st.acc_util),
                         acc_drops=jnp.asarray(0.0, jnp.float32),
                         acc_marks=jnp.asarray(0.0, jnp.float32),
                         acc_jobbytes=jnp.zeros_like(st.acc_jobbytes))
        st, _ = jax.lax.scan(tick, st, None, length=ticks_per_chunk)
        # the legacy chunk-averaged channels, via the built-in chunk-probe
        # registry (telemetry.CHUNK_PROBES — same expressions, same order)
        return st, telem.chunk_capture(cfg, statics, st, ticks_per_chunk)

    st, (u, d, m, ic, tt, jt, rj) = jax.lax.scan(chunk, st, None,
                                                 length=n_chunks)
    return RawSimOutput(iter_times=st.iter_times, iter_counts=st.iter_idx,
                        trace_util=u, trace_drops=d, trace_marks=m,
                        trace_incomm=ic, trace_t=tt, trace_jobtput=jt,
                        trace_ratio=rj, final_state=st,
                        telemetry=st.telemetry)


# Incremented once per (re)trace of the sweep program; tests pin "a K-point
# sweep costs exactly one trace" on this counter.
TRACE_COUNT = 0


@partial(jax.jit, static_argnums=(0,))
def _run_sweep(cfg: SimConfig, sweep: SweepParams) -> RawSimOutput:
    global TRACE_COUNT
    TRACE_COUNT += 1
    statics = _build_statics(cfg)
    return jax.vmap(lambda s: _run_single(cfg, statics, s))(sweep)


def _check_cfg(cfg: SimConfig) -> None:
    if abs(cfg.protocol.cc.tick_dt - cfg.dt) > 1e-12:
        raise ValueError(
            f"protocol.cc.tick_dt ({cfg.protocol.cc.tick_dt}) must equal the "
            f"simulator dt ({cfg.dt}); build CCParams with tick_dt=dt")


def _validate_sweep(cfg: SimConfig, sweep: SweepParams) -> None:
    _check_cfg(cfg)
    if sweep.slope.ndim < 1:
        raise ValueError("sweep is unbatched; every field needs a leading "
                         "sweep axis (use make_sweep / grid_sweep)")
    k = sweep_len(sweep)
    for name in SweepParams._fields:
        v = getattr(sweep, name)
        if v is not None and (v.ndim < 1 or v.shape[0] != k):
            raise ValueError(
                f"sweep field {name!r} has shape {v.shape}; expected a "
                f"leading sweep axis of length {k} (use make_sweep)")
    cas = (sweep.cassini_offset, sweep.cassini_period, sweep.cassini_eps)
    if any(c is not None for c in cas) and any(c is None for c in cas):
        raise ValueError("cassini_offset / cassini_period / cassini_eps "
                         "must be set together (or all None)")
    if cfg.faults is None:
        for name in faults_mod.FIELDS:
            if getattr(sweep, name) is not None:
                raise ValueError(
                    f"sweep carries {name!r} but cfg.faults is None — set a "
                    f"FaultSpec on the config so the fault gather is traced")
    else:
        required = cfg.faults.leaves()
        for name in faults_mod.FIELDS:
            v = getattr(sweep, name)
            if name in required and v is None:
                raise ValueError(
                    f"cfg.faults arms {name!r} but the sweep leaf is None "
                    f"(use faults.schedule / faults.identity_schedule)")
            if name not in required and v is not None:
                raise ValueError(
                    f"sweep carries {name!r} but cfg.faults does not arm "
                    f"that channel")
        e = cfg.faults.n_events
        if sweep.fault_tick.shape[-1] != e:
            raise ValueError(
                f"fault_tick has {sweep.fault_tick.shape[-1]} event rows; "
                f"cfg.faults.n_events = {e}")


def simulate_sweep(cfg: SimConfig, sweep: SweepParams) -> RawSimOutput:
    """Run K simulations batched over the sweep axis — one trace, one compile.

    ``sweep`` is a batched SweepParams (see `make_sweep` / `grid_sweep`):
    every non-None leaf carries a leading [K] axis.  The whole chunked
    `lax.scan` is vmapped over that axis, so the returned RawSimOutput's
    leaves all gain a leading [K] dimension (postprocess with
    `metrics.postprocess_sweep`).  Retraces only when the *static* config
    (topology, jobs, algorithm, K) changes — never per grid point.
    """
    _validate_sweep(cfg, sweep)
    return _run_sweep(cfg, sweep)


def lower_sweep(cfg: SimConfig, sweep: SweepParams):
    """AOT-lower the sweep program (`jax.stages.Lowered`) without running it.

    The profiling hook behind `run_plan(..., profile=True)`: callers split
    wall time into trace (`lower_sweep`), compile (`.compile()`) and execute
    (calling the compiled object), and read `.memory_analysis()` for the
    device footprint.  Shares `_run_sweep`'s jit/lowering cache (pin with
    `TRACE_COUNT` if retrace behavior matters), but `.compile()` on the
    returned object re-runs XLA, so the compile_s split is only meaningful
    for cold groups.
    """
    _validate_sweep(cfg, sweep)
    return _run_sweep.lower(cfg, sweep)


def trace_sweep(cfg: SimConfig, sweep: SweepParams):
    """Trace the sweep program (`jax.stages.Traced`) without lowering it.

    The static analyzer's entry point (repro.analysis.jaxpr_lint): the
    returned object's ``.jaxpr`` is the exact program `simulate_sweep`
    would run for this (cfg, sweep shape) — same jit entry, same jaxpr
    cache, one `TRACE_COUNT` bump for a cold config and zero for a warm
    one — so IR-level invariants (kernel presence, no f64, no callbacks)
    are proved about the real program, not a re-traced imitation.
    """
    _validate_sweep(cfg, sweep)
    return _run_sweep.trace(cfg, sweep)


def simulate(cfg: SimConfig) -> RawSimOutput:
    """Run one simulation (a K=1 `simulate_sweep`, kept for compatibility).

    Shares `_run_sweep`'s jit cache entry with K=1 sweeps of the same
    config — there is no separate single-run program anymore (the fused
    kernel takes its protocol scalars as operands, so the old "specialize
    on the config's concrete floats" path is gone; DESIGN.md §4).
    """
    _check_cfg(cfg)
    raw = _run_sweep(cfg, make_sweep(cfg))
    return jax.tree_util.tree_map(lambda x: x[0], raw)
