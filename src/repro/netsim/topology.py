"""Experiment topologies (paper Figure 6 and Figure 2).

A Topology is a static description: link capacities plus an ordered hop list
per flow.  Flows are created per job: ``sockets_per_job`` parallel flows share
each job's path (the paper uses 8 sockets for Reno, 4 for CUBIC, 1 QP for
RoCE) — statistics are aggregated per job by the protocol layer.
"""
from __future__ import annotations

import dataclasses

import numpy as np

GBPS = 1e9 / 8.0  # bytes/s


def _arr_key(a):
    if a is None:
        return None
    a = np.asarray(a)
    return (a.shape, a.dtype.str, a.tobytes())


class HashableConfig:
    """Mixin: hash/eq over dataclass fields with numpy-array support, so
    configs can be `static_argnums` of jitted entry points."""

    def _key(self):
        out = []
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            out.append(_arr_key(v) if isinstance(v, np.ndarray) else v)
        return tuple(out)

    def __hash__(self):
        return hash(self._key())

    def __eq__(self, other):
        return type(self) is type(other) and self._key() == other._key()


@dataclasses.dataclass(frozen=True, eq=False)
class Topology(HashableConfig):
    """Static routing description.

    cap:   [M] link capacities (bytes/s).
    hops:  [N, H] ordered link ids per flow, padded with -1.
    flow_to_job: [N] job id per flow.
    names: link names for reporting.
    """

    cap: np.ndarray
    hops: np.ndarray
    flow_to_job: np.ndarray
    names: tuple[str, ...]

    @property
    def n_links(self) -> int:
        return int(self.cap.shape[0])

    @property
    def n_flows(self) -> int:
        return int(self.hops.shape[0])

    @property
    def n_jobs(self) -> int:
        return int(self.flow_to_job.max()) + 1 if self.n_flows else 0

    @property
    def max_hops(self) -> int:
        return int(self.hops.shape[1])

    def routing_matrix(self) -> np.ndarray:
        """[M, N] 0/1 incidence (link l carries flow n)."""
        m = np.zeros((self.n_links, self.n_flows), dtype=np.float32)
        for n in range(self.n_flows):
            for l in self.hops[n]:
                if l >= 0:
                    m[l, n] = 1.0
        return m


def _build(cap, names, job_paths, sockets_per_job) -> Topology:
    """job_paths: list (per job) of ordered link-id lists."""
    max_h = max(len(p) for p in job_paths)
    hops, f2j = [], []
    for j, path in enumerate(job_paths):
        for _ in range(sockets_per_job):
            hops.append(list(path) + [-1] * (max_h - len(path)))
            f2j.append(j)
    return Topology(cap=np.asarray(cap, np.float64),
                    hops=np.asarray(hops, np.int32),
                    flow_to_job=np.asarray(f2j, np.int32),
                    names=tuple(names))


def dumbbell(n_jobs: int, sockets_per_job: int = 1,
             cap_gbps: float = 50.0) -> Topology:
    """Figure 6(a): every job's flows share one bottleneck link.

    (Per-server access links are dedicated in the paper's dumbbell and never
    the bottleneck, so only the shared link is modeled.)
    """
    return _build([cap_gbps * GBPS], ["bottleneck"],
                  [[0]] * n_jobs, sockets_per_job)


def triangle(sockets_per_job: int = 1, cap_gbps: float = 50.0) -> Topology:
    """Figure 2: circular dependency.

    Job1 vs Job2 on l1, Job2 vs Job3 on l2, Job1 vs Job3 on l3:
      Job1 -> [l1, l3],  Job2 -> [l2, l1],  Job3 -> [l3, l2].
    Each job crosses two links and meets a *different* competitor on each —
    the affinity graph has a loop, which defeats Cassini and Static.
    """
    cap = [cap_gbps * GBPS] * 3
    return _build(cap, ["l1", "l2", "l3"],
                  [[0, 2], [1, 0], [2, 1]], sockets_per_job)


def two_tier(job_leaf_pairs: list[tuple[int, int]], n_leaves: int = 4,
             sockets_per_job: int = 1, leaf_up_gbps: float = 50.0,
             core_gbps: float = 200.0) -> Topology:
    """Figure 6(b): two-tier leaf/spine.

    Each job j sends from leaf a to leaf b: path = [up_a, core, down_b].
    Leaf up/down links (one each per leaf) are the 50 Gbps bottlenecks; the
    core is provisioned fatter, as in the paper's Tofino fabric.
    """
    # link ids: up_0..up_{L-1}, down_0..down_{L-1}, core = 2L
    cap = ([leaf_up_gbps * GBPS] * n_leaves + [leaf_up_gbps * GBPS] * n_leaves
           + [core_gbps * GBPS])
    names = ([f"up{l}" for l in range(n_leaves)]
             + [f"down{l}" for l in range(n_leaves)] + ["core"])
    paths = []
    for (a, b) in job_leaf_pairs:
        assert 0 <= a < n_leaves and 0 <= b < n_leaves and a != b
        paths.append([a, 2 * n_leaves, n_leaves + b])
    return _build(cap, names, paths, sockets_per_job)
