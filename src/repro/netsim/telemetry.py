"""On-device probe subsystem — declarative time-series capture inside the scan.

The engine's hard-wired ``trace_*`` channels average over chunks, which is
the wrong resolution for the paper's *dynamic* claims: Fig. 5/7a plot
per-flow cwnd and throughput timelines at sub-iteration resolution, and the
headline "flows stabilize into an interleaved state within a few training
iterations" needs a *time-to-interleave* measurement, not a tail average.

This module makes capture declarative and extensible (DESIGN.md §6):

* A static `TelemetrySpec` (hashable; part of `SimConfig`, hence of the
  compile-group key) names which **probes** are armed and their decimation
  ``stride``.  Armed probes sample per-tick signals — per-flow cwnd/rate,
  per-link queue depth and RED mark rate, per-job phase state and F factor
  — into preallocated ring buffers carried through the `lax.scan` state.
* **In-scan streaming detectors** reduce the run without materializing
  dense traces: the interleave detector tracks the EWMA pairwise
  comm-overlap and records the last tick it exceeded a threshold
  (time-to-interleave = the first tick after which overlap *stays* below),
  plus a tail-stability fraction; the iteration-time sketch bins completed
  iteration times into a per-job log histogram for streaming p50/p99.
* The existing chunk-averaged ``trace_*`` channels are expressed through
  the same registry as **built-in chunk probes** (`CHUNK_PROBES`), always
  on for compatibility — `chunk_capture` emits exactly the expressions the
  engine emitted before, so telemetry-off programs are bit-identical.

**Off is free**: every hook in the engine is gated on a *python-level*
``cfg.telemetry is not None``, so an unarmed config traces the exact same
program as before this subsystem existed (pinned by tests/test_telemetry.py
and the CI telemetry gate on `engine.TRACE_COUNT`).

Custom probes register with `register_probe(name, kind, capture)`; capture
functions read a `TickSignals` view of the tick's intermediates.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Tick signals — the read-only view probes capture from
# ---------------------------------------------------------------------------

class TickSignals(NamedTuple):
    """Per-tick intermediates the engine exposes to armed probes.

    All values are *post-update* for this tick except ``rate``, which is the
    send rate the tick actually injected at (the pre-update CC rate — the
    quantity Fig. 5 plots).  ``f_job`` is only computed when the ``job_f``
    probe is armed; ``overlap`` is the interleave detector's current EWMA
    pairwise comm-overlap (None when the detector is unarmed).
    """

    tick: Array               # int32 scalar
    t: Array                  # float32 scalar, seconds
    cwnd: Array               # [N] packets
    rate: Array               # [N] bytes/s (injection rate this tick)
    bytes_ratio: Array        # [N] Algorithm 1 progress ratio
    q_len: Array              # [M] queued bytes per link
    red_prob: Array           # [M] RED mark/drop probability per link
    in_comm: Array            # [J] bool
    phase_idx: Array          # [J] int32
    iter_idx: Array           # [J] int32
    iter_done: Array          # [J] bool (an iteration completed this tick)
    iter_time: Array          # [J] seconds (valid where iter_done)
    f_job: Optional[Array] = None   # [J] mean aggressiveness factor
    job_active: Optional[Array] = None  # [J] bool padded-jobs mask
    overlap: Optional[Array] = None     # scalar EWMA pairwise overlap
    # fault-injection context (None when cfg.faults is None): the current
    # event-table row and the table's start ticks — what the reinterleave
    # detector segments its per-event statistics on
    fault_idx: Optional[Array] = None   # int32 scalar, current event row
    fault_ticks: Optional[Array] = None  # [E] int32 event start ticks


# ---------------------------------------------------------------------------
# Probe registry
# ---------------------------------------------------------------------------

class Probe(NamedTuple):
    """One registered probe: a capture function plus its shape ``kind``.

    kind decides the per-sample shape and how `collect` trims padded
    fabrics: "flow" -> [N] (trimmed to the point's own flows), "link" ->
    [M], "job" -> [J] (trimmed to active jobs), "scalar" -> [].
    """

    kind: str
    capture: Callable[[TickSignals], Array]
    doc: str = ""


_KINDS = ("flow", "link", "job", "scalar")

PROBES: dict[str, Probe] = {}


def register_probe(name: str, kind: str,
                   capture: Callable[[TickSignals], Array],
                   doc: str = "", overwrite: bool = False) -> None:
    """Add a probe to the registry so `TelemetrySpec(probes=(name, ...))`
    can arm it.  ``capture`` maps a `TickSignals` to this tick's sample."""
    if kind not in _KINDS:
        raise ValueError(f"probe {name!r}: unknown kind {kind!r} "
                         f"(expected one of {_KINDS})")
    if name in PROBES and not overwrite:
        raise ValueError(f"probe {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    PROBES[name] = Probe(kind=kind, capture=capture, doc=doc)


register_probe("flow_cwnd", "flow", lambda s: s.cwnd,
               "per-flow congestion window (packets)")
register_probe("flow_rate", "flow", lambda s: s.rate,
               "per-flow injection rate (bytes/s)")
register_probe("flow_ratio", "flow", lambda s: s.bytes_ratio,
               "per-flow Algorithm-1 bytes_ratio")
register_probe("link_queue", "link", lambda s: s.q_len,
               "per-link queued bytes")
register_probe("link_mark_rate", "link", lambda s: s.red_prob,
               "per-link RED mark/drop probability")
register_probe("job_incomm", "job", lambda s: s.in_comm.astype(jnp.float32),
               "per-job comm-phase indicator")
register_probe("job_phase", "job", lambda s: s.phase_idx.astype(jnp.float32),
               "per-job sub-phase index")
register_probe("job_iter", "job", lambda s: s.iter_idx.astype(jnp.float32),
               "per-job completed-iteration count")
register_probe("job_f", "job", lambda s: s.f_job,
               "per-job mean aggressiveness factor F")
register_probe("interleave_overlap", "scalar", lambda s: s.overlap,
               "EWMA pairwise comm-overlap (interleave detector signal)")


def probe_shape(name: str, cfg) -> tuple[int, ...]:
    kind = PROBES[name].kind
    if kind == "flow":
        return (cfg.topo.n_flows,)
    if kind == "link":
        return (cfg.topo.n_links,)
    if kind == "job":
        return (cfg.jobs.n_jobs,)
    return ()


# ---------------------------------------------------------------------------
# The spec — static, hashable, part of the compile-group key
# ---------------------------------------------------------------------------

DETECTORS = ("interleave", "iter_sketch", "reinterleave")

# "reinterleave" is opt-in (it needs cfg.faults), so it is not a default
DEFAULT_DETECTORS = ("interleave", "iter_sketch")

DEFAULT_PROBES = ("flow_cwnd", "flow_rate", "link_queue", "link_mark_rate",
                  "job_incomm", "job_iter")


@dataclasses.dataclass(frozen=True)
class TelemetrySpec:
    """Static description of what a run captures (DESIGN.md §6).

    Lives on `SimConfig.telemetry`, so arming/changing it retraces (one new
    trace per compile group — pinned by tests) while leaving unarmed
    configs' programs untouched.

    probes:    registered probe names sampled every ``stride`` ticks into a
               ring buffer of ``capacity`` slots (None: sized to hold every
               sampled tick — no wrapping).
    detectors: in-scan streaming reductions; "interleave" maintains the
               EWMA pairwise comm-overlap (time constant ``overlap_tau``
               seconds) and records time-to-interleave against
               ``overlap_threshold`` (converged only if overlap stays below
               it for the final ``hold_frac`` of the run), "iter_sketch"
               bins completed iteration times into ``sketch_bins``
               log-spaced bins on [sketch_lo, sketch_hi] seconds for
               streaming p50/p99, and "reinterleave" (opt-in; requires
               ``cfg.faults``) segments the same overlap signal by
               fault-event window — per event it records the first/last
               tick the event's table row was current, the iteration count
               at entry and the last tick overlap was bad, yielding
               per-event disruption duration and *time-to-re-interleave*
               in training iterations (DESIGN.md §8).
    """

    probes: tuple[str, ...] = DEFAULT_PROBES
    stride: int = 50
    capacity: Optional[int] = None
    detectors: tuple[str, ...] = DEFAULT_DETECTORS
    # an EWMA Jaccard above 0.5 means comm phases are majority-overlapping;
    # tau spans a fraction of an iteration so within-phase brush-ups don't
    # reset the convergence clock (picked against dense post-hoc traces —
    # tests/test_telemetry.py pins detector == NumPy replay)
    overlap_threshold: float = 0.5
    overlap_tau: float = 0.05
    hold_frac: float = 0.1
    sketch_bins: int = 64
    sketch_lo: float = 1e-4
    sketch_hi: float = 100.0

    def __post_init__(self):
        object.__setattr__(self, "probes", tuple(self.probes))
        object.__setattr__(self, "detectors", tuple(self.detectors))
        if self.stride < 1:
            raise ValueError(f"stride must be >= 1, got {self.stride}")
        for d in self.detectors:
            if d not in DETECTORS:
                raise ValueError(f"unknown detector {d!r} "
                                 f"(valid: {', '.join(DETECTORS)})")

    def wants(self, probe: str) -> bool:
        return probe in self.probes

    def needs_interleave(self) -> bool:
        # reinterleave segments the interleave detector's overlap signal,
        # so arming it arms the EWMA machinery too
        return ("interleave" in self.detectors
                or "reinterleave" in self.detectors
                or self.wants("interleave_overlap"))

    def needs_sketch(self) -> bool:
        return "iter_sketch" in self.detectors

    def needs_reinterleave(self) -> bool:
        return "reinterleave" in self.detectors

    def validate(self) -> None:
        """Check every armed probe is registered (registry may grow after a
        spec is built, so this runs at arm time, not construction)."""
        for name in self.probes:
            if name not in PROBES:
                raise ValueError(
                    f"unknown probe {name!r}; registered probes: "
                    f"{', '.join(sorted(PROBES))} (register_probe adds more)")

    def n_slots(self, n_ticks: int) -> int:
        full = -(-n_ticks // self.stride)        # ceil: ticks 0, s, 2s, ...
        return full if self.capacity is None else min(self.capacity, full)


# ---------------------------------------------------------------------------
# Scan-carried state
# ---------------------------------------------------------------------------

class TelemetryState(NamedTuple):
    """Telemetry's slice of the engine's scan carry.

    ``series`` maps armed probe name -> [cap, *shape] ring buffer;
    ``sample_tick`` records which tick each slot holds (-1 = unset), so
    `collect` can unwrap a wrapped ring chronologically.  Detector fields
    are None when the detector is unarmed (absent pytree leaves — an
    unarmed detector adds nothing to the carry).
    """

    series: dict[str, Array]
    sample_tick: Array            # [cap] int32
    n_samples: Array              # int32 total writes
    # interleave detector
    ewma_both: Optional[Array] = None      # [P2] per-pair EWMA of a&b
    ewma_either: Optional[Array] = None    # [P2] per-pair EWMA of a|b
    last_bad_tick: Optional[Array] = None  # int32 (-1: never above threshold)
    iters_at_last_bad: Optional[Array] = None  # int32
    tail_bad: Optional[Array] = None       # int32 bad ticks in tail window
    tail_ticks: Optional[Array] = None     # int32 ticks in tail window
    # iteration-time sketch
    iter_hist: Optional[Array] = None      # [J, B] int32
    # re-interleave detector: per-fault-event segmentation of the overlap
    # signal (all [E], indexed by the engine's current event row)
    ev_start_tick: Optional[Array] = None        # first tick row was current
    ev_start_iter: Optional[Array] = None        # max iter count at entry
    ev_end_tick: Optional[Array] = None          # last tick row was current
    ev_last_bad_tick: Optional[Array] = None     # last bad tick in window
    ev_iters_at_last_bad: Optional[Array] = None


def init_state(cfg, spec: TelemetrySpec) -> TelemetryState:
    """Preallocate ring buffers and detector state for one simulation."""
    spec.validate()
    cap = spec.n_slots(cfg.n_ticks)
    series = {name: jnp.zeros((cap,) + probe_shape(name, cfg), jnp.float32)
              for name in spec.probes}
    j = cfg.jobs.n_jobs
    kw: dict = {}
    if spec.needs_interleave():
        p2 = j * (j - 1) // 2
        kw.update(ewma_both=jnp.zeros((p2,), jnp.float32),
                  ewma_either=jnp.zeros((p2,), jnp.float32),
                  last_bad_tick=jnp.asarray(-1, jnp.int32),
                  iters_at_last_bad=jnp.asarray(0, jnp.int32),
                  tail_bad=jnp.asarray(0, jnp.int32),
                  tail_ticks=jnp.asarray(0, jnp.int32))
    if spec.needs_sketch():
        kw.update(iter_hist=jnp.zeros((j, spec.sketch_bins), jnp.int32))
    if spec.needs_reinterleave():
        if cfg.faults is None:
            raise ValueError(
                "the 'reinterleave' detector segments statistics by fault "
                "event, so it needs cfg.faults (a netsim.faults.FaultSpec); "
                "arm faults or drop the detector")
        e = cfg.faults.n_events
        kw.update(ev_start_tick=jnp.full((e,), -1, jnp.int32),
                  ev_start_iter=jnp.zeros((e,), jnp.int32),
                  ev_end_tick=jnp.full((e,), -1, jnp.int32),
                  ev_last_bad_tick=jnp.full((e,), -1, jnp.int32),
                  ev_iters_at_last_bad=jnp.zeros((e,), jnp.int32))
    return TelemetryState(series=series,
                          sample_tick=jnp.full((cap,), -1, jnp.int32),
                          n_samples=jnp.asarray(0, jnp.int32), **kw)


def tick_update(cfg, spec: TelemetrySpec, st: TelemetryState,
                sig: TickSignals) -> TelemetryState:
    """One telemetry step: detectors first (so the ``interleave_overlap``
    probe sees this tick's value), then decimated ring-buffer capture."""
    kw: dict = {}
    j = sig.in_comm.shape[0]

    if spec.needs_interleave():
        # trace-time constant on the static job count, not per-tick work
        ia, ib = np.triu_indices(j, 1)          # lint: allow(np-in-scan)
        a = sig.in_comm[ia]
        b = sig.in_comm[ib]
        if sig.job_active is not None:
            w = (sig.job_active[ia] & sig.job_active[ib]).astype(jnp.float32)
        else:
            w = jnp.ones((len(ia),), jnp.float32)
        both = w * (a & b).astype(jnp.float32)
        either = w * (a | b).astype(jnp.float32)
        alpha = jnp.float32(-math.expm1(-cfg.dt / spec.overlap_tau))
        ewma_both = st.ewma_both + alpha * (both - st.ewma_both)
        ewma_either = st.ewma_either + alpha * (either - st.ewma_either)
        per_pair = ewma_both / jnp.maximum(ewma_either, 1e-6)
        overlap = jnp.sum(per_pair * w) / jnp.maximum(jnp.sum(w), 1.0)
        bad = overlap > spec.overlap_threshold
        active_iters = sig.iter_idx
        if sig.job_active is not None:
            active_iters = jnp.where(sig.job_active, sig.iter_idx, 0)
        cur_iters = (jnp.max(active_iters) if j
                     else jnp.asarray(0, jnp.int32))
        in_tail = sig.tick >= (cfg.n_ticks // 2)
        kw.update(
            ewma_both=ewma_both, ewma_either=ewma_either,
            last_bad_tick=jnp.where(bad, sig.tick, st.last_bad_tick),
            iters_at_last_bad=jnp.where(bad, cur_iters,
                                        st.iters_at_last_bad),
            tail_bad=st.tail_bad + (bad & in_tail).astype(jnp.int32),
            tail_ticks=st.tail_ticks + in_tail.astype(jnp.int32))
        sig = sig._replace(overlap=overlap)

        if spec.needs_reinterleave():
            # segment the same bad/cur_iters signals by the current fault
            # event row: one scatter per field, no control flow
            ei = sig.fault_idx
            first = st.ev_start_tick[ei] < 0
            kw.update(
                ev_start_tick=st.ev_start_tick.at[ei].set(
                    jnp.where(first, sig.tick, st.ev_start_tick[ei])),
                ev_start_iter=st.ev_start_iter.at[ei].set(
                    jnp.where(first, cur_iters, st.ev_start_iter[ei])),
                ev_end_tick=st.ev_end_tick.at[ei].set(sig.tick),
                ev_last_bad_tick=st.ev_last_bad_tick.at[ei].set(
                    jnp.where(bad, sig.tick, st.ev_last_bad_tick[ei])),
                ev_iters_at_last_bad=st.ev_iters_at_last_bad.at[ei].set(
                    jnp.where(bad, cur_iters,
                              st.ev_iters_at_last_bad[ei])))

    if spec.needs_sketch():
        log_lo = math.log(spec.sketch_lo)
        inv_w = spec.sketch_bins / (math.log(spec.sketch_hi) - log_lo)
        x = jnp.clip(sig.iter_time, spec.sketch_lo, spec.sketch_hi)
        bins = jnp.clip((jnp.log(x) - jnp.float32(log_lo))
                        * jnp.float32(inv_w), 0,
                        spec.sketch_bins - 1).astype(jnp.int32)
        kw["iter_hist"] = st.iter_hist.at[jnp.arange(j), bins].add(
            sig.iter_done.astype(jnp.int32))

    cap = st.sample_tick.shape[0]
    take = (sig.tick % spec.stride) == 0
    slot = (sig.tick // spec.stride) % cap
    series = {}
    for name in spec.probes:
        val = jnp.asarray(PROBES[name].capture(sig), jnp.float32)
        buf = st.series[name]
        series[name] = buf.at[slot].set(jnp.where(take, val, buf[slot]))
    return st._replace(
        series=series,
        sample_tick=st.sample_tick.at[slot].set(
            jnp.where(take, sig.tick, st.sample_tick[slot])),
        n_samples=st.n_samples + take.astype(jnp.int32),
        **kw)


# ---------------------------------------------------------------------------
# Built-in chunk probes — the legacy trace_* channels
# ---------------------------------------------------------------------------

def _trace_ratio(cfg, statics, st, ticks_per_chunk):
    n_jobs = st.acc_jobbytes.shape[0]
    flows_per_job = jnp.zeros((n_jobs,)).at[statics.f2j].add(1.0)
    return (jnp.zeros((n_jobs,)).at[statics.f2j]
            .add(st.proto.det.bytes_ratio) / flows_per_job)


# name -> capture(cfg, statics, st, ticks_per_chunk); insertion order is the
# RawSimOutput field order (trace_util .. trace_ratio).  These are the
# always-on chunk-averaged channels the engine emitted before the probe
# subsystem existed; the expressions are kept identical so telemetry-off
# outputs stay bit-for-bit.
CHUNK_PROBES: dict[str, Callable] = {
    "trace_util": lambda cfg, statics, st, tpc: st.acc_util / tpc,
    "trace_drops": lambda cfg, statics, st, tpc: st.acc_drops,
    "trace_marks": lambda cfg, statics, st, tpc: st.acc_marks,
    "trace_incomm": lambda cfg, statics, st, tpc: st.in_comm,
    "trace_t": lambda cfg, statics, st, tpc:
        st.tick.astype(jnp.float32) * cfg.dt,
    "trace_jobtput": lambda cfg, statics, st, tpc:
        st.acc_jobbytes / (tpc * cfg.dt),
    "trace_ratio": _trace_ratio,
}


def chunk_capture(cfg, statics, st, ticks_per_chunk) -> tuple:
    """The per-chunk trace outputs, in `RawSimOutput` field order."""
    return tuple(fn(cfg, statics, st, ticks_per_chunk)
                 for fn in CHUNK_PROBES.values())


# ---------------------------------------------------------------------------
# Host-side view
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FaultEventReport:
    """Re-interleave verdict for one fault-event window (DESIGN.md §8).

    ``disrupted`` is whether overlap ever exceeded the threshold inside the
    window; ``reconverged`` whether it then stayed below for the window's
    final hold fraction.  ``reinterleave_iters`` counts training iterations
    from the event's start to the last bad tick — the paper-facing
    "re-stabilizes within a few training iterations" number (0.0 when the
    event never disrupted; inf when it never re-converged).
    """

    event: int
    start_tick: int
    start_t: float
    end_tick: int
    start_iter: int
    disrupted: bool
    reconverged: bool
    disruption_s: float
    reinterleave_iters: float


@dataclasses.dataclass
class TelemetryResult:
    """Numpy-side view of one run's telemetry (attached to `SimResult`).

    ``series[name]`` is [S, *shape] in chronological sample order and
    ``t``/``ticks`` are the matching sample times; padded fabrics are
    trimmed to the point's own flows/jobs.  Detector outputs are floats
    (inf = the run never converged; nan = detector unarmed).
    """

    spec: TelemetrySpec
    t: np.ndarray                     # [S] seconds
    ticks: np.ndarray                 # [S] int32
    series: dict[str, np.ndarray]     # name -> [S, ...]
    n_samples: int
    # interleave detector
    time_to_interleave_s: float = float("nan")
    time_to_interleave_iters: float = float("nan")
    interleave_stability: float = float("nan")
    converged: bool = False
    # iteration-time sketch
    iter_hist: Optional[np.ndarray] = None    # [J, B]
    bin_edges: Optional[np.ndarray] = None    # [B + 1] seconds
    # re-interleave detector (one report per *observed* fault event —
    # table rows whose window never arrived inside the run are skipped)
    fault_events: Optional[list] = None       # list[FaultEventReport]
    all_events_reconverged: bool = False
    max_reinterleave_iters: float = float("nan")

    def timeline(self, probe: str) -> tuple[np.ndarray, np.ndarray]:
        """(t, values) for one armed probe's decimated series."""
        if probe not in self.series:
            raise KeyError(f"probe {probe!r} was not armed "
                           f"(armed: {', '.join(self.series)})")
        return self.t, self.series[probe]

    def iter_quantile(self, q: float, job: Optional[int] = None) -> float:
        """Streaming quantile of iteration times from the log-histogram
        sketch (accurate to one bin width — ~20% at the default 64 bins
        over 6 decades).  job=None pools all jobs."""
        if self.iter_hist is None:
            raise ValueError("iter_sketch detector was not armed")
        h = (self.iter_hist.sum(axis=0) if job is None
             else self.iter_hist[job])
        total = int(h.sum())
        if total == 0:
            return float("nan")
        idx = int(np.searchsorted(np.cumsum(h), q * total, side="left"))
        idx = min(idx, h.shape[0] - 1)
        centers = np.sqrt(self.bin_edges[:-1] * self.bin_edges[1:])
        return float(centers[idx])

    @property
    def p50_iter(self) -> float:
        return self.iter_quantile(0.50)

    @property
    def p99_iter(self) -> float:
        return self.iter_quantile(0.99)


def collect(cfg, state: TelemetryState,
            n_jobs: Optional[int] = None) -> TelemetryResult:
    """Convert one run's final `TelemetryState` into a `TelemetryResult`.

    ``cfg`` is the *point's own* config (unpadded): flow-kind series are
    trimmed to its flow count and job-kind series to ``n_jobs`` (padded
    groups put the point's flows/jobs in a prefix — DESIGN.md §5).
    """
    spec = cfg.telemetry
    ticks = np.asarray(state.sample_tick)
    valid = np.nonzero(ticks >= 0)[0]
    order = valid[np.argsort(ticks[valid], kind="stable")]
    n = cfg.jobs.n_jobs if n_jobs is None else n_jobs
    n_flows = cfg.topo.n_flows
    series = {}
    for name in spec.probes:
        buf = np.asarray(state.series[name])[order]
        kind = PROBES[name].kind
        if kind == "flow":
            buf = buf[:, :n_flows]
        elif kind == "job":
            buf = buf[:, :n]
        series[name] = buf

    out = TelemetryResult(
        spec=spec, t=ticks[order].astype(np.float64) * cfg.dt,
        ticks=ticks[order], series=series,
        n_samples=int(np.asarray(state.n_samples)))

    if spec.needs_interleave():
        last_bad = int(np.asarray(state.last_bad_tick))
        hold = int(round(spec.hold_frac * cfg.n_ticks))
        tail_n = int(np.asarray(state.tail_ticks))
        out.interleave_stability = (
            1.0 - int(np.asarray(state.tail_bad)) / tail_n if tail_n
            else float("nan"))
        if last_bad < 0:
            out.converged = True
            out.time_to_interleave_s = 0.0
            out.time_to_interleave_iters = 0.0
        elif last_bad < cfg.n_ticks - hold:
            out.converged = True
            out.time_to_interleave_s = (last_bad + 1) * cfg.dt
            out.time_to_interleave_iters = float(
                np.asarray(state.iters_at_last_bad))
        else:
            out.converged = False
            out.time_to_interleave_s = float("inf")
            out.time_to_interleave_iters = float("inf")

    if spec.needs_sketch():
        out.iter_hist = np.asarray(state.iter_hist)[:n]
        b = spec.sketch_bins
        out.bin_edges = spec.sketch_lo * (
            spec.sketch_hi / spec.sketch_lo) ** (np.arange(b + 1) / b)

    if spec.needs_reinterleave():
        starts = np.asarray(state.ev_start_tick)
        start_iters = np.asarray(state.ev_start_iter)
        ends = np.asarray(state.ev_end_tick)
        last_bads = np.asarray(state.ev_last_bad_tick)
        bad_iters = np.asarray(state.ev_iters_at_last_bad)
        reports = []
        for e in np.nonzero(starts >= 0)[0]:
            s, t_end = int(starts[e]), int(ends[e])
            window = t_end - s + 1
            hold = int(round(spec.hold_frac * window))
            last_bad = int(last_bads[e])
            rep = FaultEventReport(
                event=int(e), start_tick=s, start_t=s * cfg.dt,
                end_tick=t_end, start_iter=int(start_iters[e]),
                disrupted=last_bad >= 0, reconverged=True,
                disruption_s=0.0, reinterleave_iters=0.0)
            if last_bad >= 0:
                if last_bad <= t_end - hold:
                    rep.disruption_s = (last_bad + 1 - s) * cfg.dt
                    rep.reinterleave_iters = float(
                        int(bad_iters[e]) - rep.start_iter)
                else:
                    rep.reconverged = False
                    rep.disruption_s = float("inf")
                    rep.reinterleave_iters = float("inf")
            reports.append(rep)
        out.fault_events = reports
        out.all_events_reconverged = all(r.reconverged for r in reports)
        out.max_reinterleave_iters = (
            max(r.reinterleave_iters for r in reports) if reports else 0.0)
    return out
