"""netsim — vectorized discrete-time fluid network simulator.

The evaluation substrate replacing the paper's 12-server testbed: links with
FIFO queues and RED/ECN, per-flow multi-hop routing, RTT-delayed feedback,
and periodic DNN-job traffic — all stepped by a single `jax.lax.scan`.
"""

from repro.netsim.topology import Topology, dumbbell, triangle, two_tier
from repro.netsim.engine import CassiniSchedule, JobSpec, SimConfig, simulate
from repro.netsim.metrics import (
    SimResult,
    interleave_score,
    iteration_times,
    mean_pairwise_interleave,
    postprocess,
    speedup_stats,
)

__all__ = [
    "Topology", "dumbbell", "triangle", "two_tier",
    "CassiniSchedule", "SimConfig", "JobSpec", "simulate",
    "SimResult", "interleave_score", "iteration_times",
    "mean_pairwise_interleave", "postprocess", "speedup_stats",
]
