"""netsim — vectorized discrete-time fluid network simulator.

The evaluation substrate replacing the paper's 12-server testbed: links with
FIFO queues and RED/ECN, per-flow multi-hop routing, RTT-delayed feedback,
and periodic DNN-job traffic — all stepped by a single `jax.lax.scan`.
Parameter/seed sweeps batch over a leading vmap axis (`simulate_sweep`):
one trace, one compile, K simulations per device program.
"""

from repro.netsim.topology import Topology, dumbbell, triangle, two_tier
from repro.netsim.engine import (
    CassiniSchedule,
    JobSpec,
    SimConfig,
    SweepParams,
    grid_sweep,
    make_sweep,
    simulate,
    simulate_sweep,
    sweep_len,
    sweep_of,
)
from repro.netsim.metrics import (
    SimResult,
    interleave_score,
    iteration_times,
    mean_pairwise_interleave,
    postprocess,
    postprocess_sweep,
    speedup_stats,
    sweep_speedup_stats,
)

__all__ = [
    "Topology", "dumbbell", "triangle", "two_tier",
    "CassiniSchedule", "SimConfig", "JobSpec", "simulate",
    "SweepParams", "simulate_sweep", "make_sweep", "grid_sweep",
    "sweep_len", "sweep_of",
    "SimResult", "interleave_score", "iteration_times",
    "mean_pairwise_interleave", "postprocess", "postprocess_sweep",
    "speedup_stats", "sweep_speedup_stats",
]
