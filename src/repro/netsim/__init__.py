"""netsim — vectorized discrete-time fluid network simulator.

The evaluation substrate replacing the paper's 12-server testbed: links with
FIFO queues and RED/ECN, per-flow multi-hop routing, RTT-delayed feedback,
and periodic DNN-job traffic — all stepped by a single `jax.lax.scan`.
Parameter/seed sweeps batch over a leading vmap axis (`simulate_sweep`):
one trace, one compile, K simulations per device program.  The experiment
layer (`Axis`/`Plan`/`run_plan`) declares whole evaluation matrices over
static *and* dynamic axes and lowers them onto that sweep axis, one compile
group per distinct static signature.  Workload *values* — phase programs,
straggle probabilities, Cassini schedules, Static factors — are traced
sweep leaves, so straggler/compat grids fold into one group per variant;
job-count grids pad + mask into a single group; K optionally shards across
local devices; and `run_plan(..., cache_dir=)` makes runs resumable.
"""

from repro.netsim.topology import Topology, dumbbell, triangle, two_tier
from repro.netsim.engine import (
    CassiniSchedule,
    JobSpec,
    SimConfig,
    SweepParams,
    SweepPoint,
    grid_sweep,
    make_sweep,
    simulate,
    simulate_sweep,
    sweep_len,
    sweep_of,
    sweep_slice,
)
from repro.netsim.experiment import (
    Axis,
    GroupError,
    GroupProfile,
    Plan,
    PlanProfile,
    PlanResult,
    prune_cache,
    restrict_workload,
    run_plan,
)
from repro.netsim.faults import (
    FaultEvent,
    FaultSchedule,
    FaultSpec,
    blackhole,
    identity_schedule,
    job_arrives,
    job_departs,
    link_flap,
    straggle_burst,
)
from repro.netsim.faults import schedule as fault_schedule
from repro.netsim.metrics import (
    SimResult,
    convergence_iteration,
    interleave_score,
    iter_time_quantile,
    iteration_times,
    mean_pairwise_interleave,
    postprocess,
    postprocess_sweep,
    probe_timeline,
    speedup_stats,
    sweep_speedup_stats,
    time_to_interleave,
)
from repro.netsim.telemetry import (
    TelemetryResult,
    TelemetrySpec,
    register_probe,
)

__all__ = [
    "Topology", "dumbbell", "triangle", "two_tier",
    "CassiniSchedule", "SimConfig", "JobSpec", "simulate",
    "SweepParams", "SweepPoint", "simulate_sweep", "make_sweep",
    "grid_sweep", "sweep_len", "sweep_of", "sweep_slice",
    "Axis", "Plan", "PlanResult", "GroupError", "GroupProfile",
    "PlanProfile", "prune_cache", "restrict_workload", "run_plan",
    "FaultSpec", "FaultEvent", "FaultSchedule", "fault_schedule",
    "identity_schedule", "job_arrives", "job_departs", "link_flap",
    "blackhole", "straggle_burst",
    "SimResult", "interleave_score", "iteration_times",
    "mean_pairwise_interleave", "postprocess", "postprocess_sweep",
    "speedup_stats", "sweep_speedup_stats",
    "TelemetrySpec", "TelemetryResult", "register_probe",
    "probe_timeline", "time_to_interleave", "convergence_iteration",
    "iter_time_quantile",
]
