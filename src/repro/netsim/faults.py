"""Declarative, *traced* fault injection — job churn, link flaps, blackholes.

The paper's robustness claim is dynamic: MLTCP "stabilizes flows of
different jobs into an interleaved state within a few training iterations,
regardless of the number of competing flows or the start time of each flow"
(§1, §5.4).  Proving it needs more than cold starts — multi-tenant fabrics
are churn-dominated (CASSINI re-packs placements, migration-based
defragmenters move jobs continuously), so this module perturbs running
simulations and lets the telemetry layer measure *re*-convergence.

The design follows the config split the rest of netsim uses (DESIGN.md §3,
§8):

* A hashable `FaultSpec` on ``SimConfig.faults`` declares the fault
  *structure* — how many schedule rows (``n_events``) and which channels
  are armed (churn / link flaps / blackholes / straggle bursts).  It is
  part of the compile-group key, exactly like ``telemetry``: arming faults
  traces a new program, ``faults=None`` traces the pre-fault program
  bit-for-bit (pinned by tests/test_faults.py).
* The fault *schedule values* ride in as `SweepParams` leaves
  (``fault_tick`` [E], ``fault_job_active`` [E, J], ``fault_link_scale``
  [E, M], ``fault_blackhole`` [E, N], ``fault_straggle`` [E, J]), so a
  churn grid (schedule x seed x variant) joins existing compile groups
  instead of splitting them — the PR-4 workload-axis pattern.

The event table is a step function over ticks: row ``e`` is in effect from
``fault_tick[e]`` until the next row's tick (rows sorted ascending; row 0
is the identity baseline at tick 0).  The engine gathers the current row
once per tick (``sum(fault_tick <= tick) - 1``) and applies it with
``jnp.where`` at the engine/link level — capacity scaling in the link
server, activity masking in the job phase machine, first-hop null-routing
of blackholed flows — never inside the CC-tick kernel, so the fused Pallas
path stays engaged with ``FALLBACK_COUNT == 0``.

`schedule` compiles a list of declarative `FaultEvent`s (from the builder
helpers below) into the event table on a concrete config's fabric;
`identity_schedule` emits an all-no-op table for a spec, which runs
bit-identical to an un-faulted simulation (the exact-no-op property every
channel is built around: ``& True``, ``* 1.0``, ``+ 0.0``, ``where(False)``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "FIELDS", "FaultSpec", "FaultEvent", "FaultSchedule",
    "schedule", "identity_schedule",
    "job_departs", "job_arrives", "link_flap", "blackhole",
    "straggle_burst",
]

# Every SweepParams leaf the fault layer can occupy, in field order.
FIELDS = ("fault_tick", "fault_job_active", "fault_link_scale",
          "fault_blackhole", "fault_straggle")

# channel name -> the SweepParams leaf its values ride in
_CHANNEL_FIELD = {
    "churn": "fault_job_active",
    "link": "fault_link_scale",
    "blackhole": "fault_blackhole",
    "straggle": "fault_straggle",
}


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Static fault structure — lives on ``SimConfig.faults``.

    ``n_events`` fixes the event-table row count (a traced-array *shape*,
    hence static); the channel flags decide which schedule leaves exist.
    Two configs with equal specs share a compile group even when their
    schedules differ — the schedule is data, not structure.
    """

    n_events: int
    churn: bool = False             # job arrival/departure masks
    link_flaps: bool = False        # per-link capacity multipliers
    blackholes: bool = False        # per-flow first-hop null routes
    straggle_bursts: bool = False   # additive straggle-probability boosts

    def __post_init__(self):
        if self.n_events < 1:
            raise ValueError(f"FaultSpec needs n_events >= 1 "
                             f"(row 0 is the identity baseline); "
                             f"got {self.n_events}")

    def leaves(self) -> tuple[str, ...]:
        """The SweepParams leaves this spec requires (always the tick
        column, plus one table per armed channel)."""
        out = ["fault_tick"]
        if self.churn:
            out.append("fault_job_active")
        if self.link_flaps:
            out.append("fault_link_scale")
        if self.blackholes:
            out.append("fault_blackhole")
        if self.straggle_bursts:
            out.append("fault_straggle")
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One declarative fault edit, before compilation onto a tick grid.

    ``channel`` is "churn" | "link" | "blackhole" | "straggle".  Churn
    edits are *persistent* (a departure holds until the next arrival);
    the windowed channels apply on ``[t, t_end)`` (``t_end=None``: until
    the end of the run).  ``index`` selects jobs / links / flows (empty
    tuple = all of them); ``value`` is the mask/scale/boost applied.
    """

    channel: str
    t: float
    t_end: Optional[float]
    index: tuple
    value: float

    def __post_init__(self):
        if self.channel not in _CHANNEL_FIELD:
            raise ValueError(f"unknown fault channel {self.channel!r} "
                             f"(valid: {', '.join(_CHANNEL_FIELD)})")
        if self.t < 0.0:
            raise ValueError(f"fault event starts at t={self.t} < 0")
        if self.t_end is not None and self.t_end <= self.t:
            raise ValueError(f"fault event window [{self.t}, {self.t_end}) "
                             f"is empty")


def job_departs(t: float, job: int) -> FaultEvent:
    """Job ``job`` leaves the fabric at ``t`` (migration / preemption):
    its compute clock freezes and its flows stop injecting until a
    matching `job_arrives`."""
    return FaultEvent("churn", t, None, (int(job),), 0.0)


def job_arrives(t: float, job: int) -> FaultEvent:
    """Job ``job`` (re)joins the fabric at ``t`` and resumes where its
    phase machine stopped — an interrupted comm phase restarts with a
    fresh quota."""
    return FaultEvent("churn", t, None, (int(job),), 1.0)


def link_flap(t: float, t_end: Optional[float], link: int,
              scale: float) -> FaultEvent:
    """Link ``link`` serves at ``scale`` x nominal capacity on
    ``[t, t_end)`` — 0.5 is a degraded optic, 0.0 a hard down."""
    if scale < 0.0:
        raise ValueError(f"link_flap scale must be >= 0, got {scale}")
    return FaultEvent("link", t, t_end, (int(link),), float(scale))


def blackhole(t: float, t_end: Optional[float],
              flows: Sequence[int]) -> FaultEvent:
    """Flows in ``flows`` are null-routed at their first hop on
    ``[t, t_end)``: injected bytes vanish as drops (loss-signaled after
    the usual feedback delay, retransmitted when the hole closes)."""
    flows = tuple(int(f) for f in flows)
    if not flows:
        raise ValueError("blackhole needs at least one flow index")
    return FaultEvent("blackhole", t, t_end, flows, 1.0)


def straggle_burst(t: float, t_end: Optional[float], prob: float,
                   jobs: Sequence[int] = ()) -> FaultEvent:
    """Additive straggle-probability boost on ``[t, t_end)`` for ``jobs``
    (empty: every job) — a noisy-neighbor / thermal-throttling window."""
    if not 0.0 <= prob <= 1.0:
        raise ValueError(f"straggle_burst prob must be in [0, 1], got {prob}")
    return FaultEvent("straggle", t, t_end, tuple(int(j) for j in jobs),
                      float(prob))


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A compiled schedule: the spec plus its event-table values.

    ``spec`` goes on the config (``dataclasses.replace(cfg, faults=s.spec)``)
    and ``overrides()`` feeds `make_sweep` / a plan's schedule axis — the
    values are plain numpy, so they hash into the point cache key and stack
    onto the batched sweep like any other dynamic leaf.
    """

    spec: FaultSpec
    values: dict                      # leaf name -> np.ndarray event table

    def overrides(self) -> dict:
        return dict(self.values)


def _identity_values(spec: FaultSpec, j: int, m: int, n: int,
                     e: Optional[int] = None) -> dict:
    e = spec.n_events if e is None else e
    values: dict = {"fault_tick": np.zeros((e,), np.int32)}
    if spec.churn:
        values["fault_job_active"] = np.ones((e, j), bool)
    if spec.link_flaps:
        values["fault_link_scale"] = np.ones((e, m), np.float32)
    if spec.blackholes:
        values["fault_blackhole"] = np.zeros((e, n), bool)
    if spec.straggle_bursts:
        values["fault_straggle"] = np.zeros((e, j), np.float32)
    return values


def identity_schedule(cfg, spec: FaultSpec) -> FaultSchedule:
    """The all-no-op schedule for ``spec`` on ``cfg``'s fabric: every row
    fires at tick 0 with identity values, so the simulation runs
    bit-identical to ``faults=None`` (pinned by tests/test_faults.py)."""
    return FaultSchedule(spec=spec, values=_identity_values(
        spec, cfg.jobs.n_jobs, cfg.topo.n_links, cfg.topo.n_flows))


def _to_tick(t: float, dt: float) -> int:
    return max(0, int(round(t / dt)))


def schedule(cfg, events: Sequence[FaultEvent], *,
             n_events: Optional[int] = None,
             spec: Optional[FaultSpec] = None) -> FaultSchedule:
    """Compile declarative events into the event table on ``cfg``'s fabric.

    Boundary times (every event start and window end, plus t=0) become the
    table's rows; each row holds the *full* channel state in effect from
    its tick — churn edits forward-fill (persistent), windowed channels
    apply where ``start <= row_tick < end``.  ``n_events`` pads the table
    (repeating the final row) so schedules of different event counts share
    one `FaultSpec` — and therefore one compile group; ``spec`` pins the
    armed channels the same way (channels the events never touch get
    identity columns).
    """
    events = list(events)
    dt, j = cfg.dt, cfg.jobs.n_jobs
    m, n = cfg.topo.n_links, cfg.topo.n_flows
    used = {ev.channel for ev in events}

    for ev in events:
        bound = {"churn": j, "link": m, "blackhole": n, "straggle": j}
        for i in ev.index:
            if not 0 <= i < bound[ev.channel]:
                raise ValueError(
                    f"fault event {ev.channel!r} indexes {i}, but the "
                    f"fabric has {bound[ev.channel]} "
                    f"{'jobs' if ev.channel in ('churn', 'straggle') else ev.channel + 's'}")

    pinned = spec is not None
    if spec is None:
        spec = FaultSpec(
            n_events=1, churn="churn" in used, link_flaps="link" in used,
            blackholes="blackhole" in used,
            straggle_bursts="straggle" in used)   # n_events sized below
    else:
        missing = {c for c in used
                   if not getattr(spec, {"churn": "churn",
                                         "link": "link_flaps",
                                         "blackhole": "blackholes",
                                         "straggle": "straggle_bursts"}[c])}
        if missing:
            raise ValueError(f"schedule uses channel(s) {sorted(missing)} "
                             f"the given FaultSpec does not arm")

    bounds = {0}
    for ev in events:
        bounds.add(_to_tick(ev.t, dt))
        if ev.t_end is not None:
            bounds.add(_to_tick(ev.t_end, dt))
    ticks = sorted(bounds)
    if n_events is None and pinned:
        n_events = spec.n_events      # an explicit spec fixes the row count
    if n_events is not None and len(ticks) > n_events:
        raise ValueError(f"schedule needs {len(ticks)} event rows but "
                         f"n_events={n_events}")
    e_used = len(ticks)
    e_total = (e_used if n_events is None else n_events)
    if spec.n_events != e_total:
        spec = dataclasses.replace(spec, n_events=e_total)

    values = _identity_values(spec, j, m, n, e=e_total)
    tick_col = values["fault_tick"]
    tick_col[:e_used] = ticks
    tick_col[e_used:] = ticks[-1]     # padding rows duplicate the last row

    churn_edits = sorted((ev for ev in events if ev.channel == "churn"),
                         key=lambda ev: _to_tick(ev.t, dt))
    for r, bt in enumerate(ticks):
        for ev in churn_edits:                    # persistent forward-fill
            if _to_tick(ev.t, dt) <= bt:
                values["fault_job_active"][r, list(ev.index)] = bool(ev.value)
        for ev in events:
            if ev.channel == "churn":
                continue
            t0 = _to_tick(ev.t, dt)
            t1 = None if ev.t_end is None else _to_tick(ev.t_end, dt)
            if not (t0 <= bt and (t1 is None or bt < t1)):
                continue
            if ev.channel == "link":              # compose: correlated flaps
                values["fault_link_scale"][r, list(ev.index)] *= ev.value
            elif ev.channel == "blackhole":
                values["fault_blackhole"][r, list(ev.index)] = True
            elif ev.channel == "straggle":
                idx = list(ev.index) if ev.index else slice(None)
                values["fault_straggle"][r, idx] += ev.value
    if spec.straggle_bursts:
        np.clip(values["fault_straggle"], 0.0, 1.0,
                out=values["fault_straggle"])
    for r in range(e_used, e_total):              # padding rows: copy values
        for name in values:
            if name != "fault_tick":
                values[name][r] = values[name][e_used - 1]
    return FaultSchedule(spec=spec, values=values)
