"""One accessor over the runtime health counters.

Two process-global counters guard the repo's fusion story: the engine bumps
``engine.TRACE_COUNT`` once per (re)trace of the sweep program, and the
kernel wrapper bumps ``ops.FALLBACK_COUNT`` once per trace that routed the
CC tick through the jnp oracle instead of the fused Pallas kernel.  Before
this module every consumer re-implemented the same fragile pokes —
``getattr(sys.modules.get("repro.kernels.ops"), "FALLBACK_COUNT", 0)`` in
`experiment.py`, in ci.yml heredocs, in benchmark suites.  Now there is one
surface:

    from repro.netsim import counters

    with counters.watch() as w:
        run_plan(plan)
    assert w.traces == 2 and w.fallbacks == 0

``watch()`` snapshots both counters at entry; the returned handle's
``.traces`` / ``.fallbacks`` are live deltas (they keep counting after the
``with`` block exits, so reading them post-exit sees everything the block
did).  Reading never imports ``repro.kernels`` — a plan that never enables
``use_pallas_kernel`` shouldn't pay the kernel import.
"""
from __future__ import annotations

import contextlib
import sys

__all__ = ["traces", "fallbacks", "reset_fallback_warnings",
           "watch", "CounterWatch"]


def traces() -> int:
    """Current engine.TRACE_COUNT (sweep-program traces this process)."""
    from repro.netsim import engine

    return engine.TRACE_COUNT


def fallbacks() -> int:
    """Current ops.FALLBACK_COUNT without importing the kernels package
    (0 when repro.kernels.ops was never imported — nothing can have fallen
    back if the wrapper never loaded)."""
    mod = sys.modules.get("repro.kernels.ops")
    return getattr(mod, "FALLBACK_COUNT", 0) if mod is not None else 0


def reset_fallback_warnings() -> None:
    """Re-arm ops.py's once-per-reason fallback warning (no-op when the
    kernels were never imported).  `run_plan` calls this per plan so each
    plan warns at most once per fallback reason."""
    mod = sys.modules.get("repro.kernels.ops")
    if mod is not None:
        mod.reset_fallback_warnings()


class CounterWatch:
    """Live deltas of both counters since construction."""

    def __init__(self) -> None:
        self._traces0 = traces()
        self._fallbacks0 = fallbacks()

    @property
    def traces(self) -> int:
        return traces() - self._traces0

    @property
    def fallbacks(self) -> int:
        return fallbacks() - self._fallbacks0


@contextlib.contextmanager
def watch(*, reset_warnings: bool = False):
    """Context manager yielding a `CounterWatch` over the enclosed work.

    ``reset_warnings=True`` additionally re-arms the once-per-reason kernel
    fallback warning at entry (the per-plan semantics `run_plan` wants).
    """
    if reset_warnings:
        reset_fallback_warnings()
    yield CounterWatch()
