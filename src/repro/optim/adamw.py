"""AdamW with configurable state dtypes.

``state_dtype="bfloat16"`` halves the optimizer-state footprint (the trick
that fits llama4-maverick-400b's states on a 16 GB/chip v5e pod; cf.
DeepSeek-V3's bf16 Adam moments).  All math runs in float32 regardless.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"       # "bfloat16" halves m/v memory


class AdamWState(NamedTuple):
    step: Array
    m: Any
    v: Any


def adamw_init(cfg: AdamWConfig, params: Any) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(z, params),
                      v=jax.tree.map(z, params))


def global_norm(tree: Any) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, state: AdamWState, params: Any, grads: Any,
                 lr_scale: Array | float = 1.0) -> tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    treedef = jax.tree.structure(params)
    leaves = jax.tree.leaves(out, is_leaf=lambda x: isinstance(x, tuple))
    newp = jax.tree.unflatten(treedef, [l[0] for l in leaves])
    newm = jax.tree.unflatten(treedef, [l[1] for l in leaves])
    newv = jax.tree.unflatten(treedef, [l[2] for l in leaves])
    return newp, AdamWState(step=step, m=newm, v=newv), {"grad_norm": gnorm}
