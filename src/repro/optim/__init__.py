"""optim — AdamW + schedules + distributed-optimization tricks."""

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.optim.grad_compress import (
    CompressionConfig,
    compress_gradients,
    init_error_feedback,
)

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
    "CompressionConfig", "compress_gradients", "init_error_feedback",
]
