"""Gradient compression with error feedback (distributed-optimization trick;
paper §1 cites QSGD [6] / Deep Gradient Compression [47] as the standard
bandwidth-reduction family MLTCP composes with).

Two schemes, both with error-feedback residual accumulation so compression
error is re-injected next step (required for convergence):

  * "topk":  keep the top fraction of entries per tensor (magnitude).
  * "int8":  per-tensor symmetric int8 quantization.

`compress_gradients` returns the *decompressed* gradients (what the step
applies after the all-reduce) plus the new residuals; `wire_bytes` reports
the bytes a NIC would carry, which feeds the cluster simulator's comm model
(this is how a gradient-compression job changes its MLTCP total_bytes).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "none"          # "none" | "topk" | "int8"
    topk_frac: float = 0.01


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_tensor(g: Array, frac: float) -> Array:
    flat = g.reshape(-1)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def _int8_tensor(g: Array) -> Array:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    return q * scale


def compress_gradients(cfg: CompressionConfig, grads: Any, residual: Any
                       ) -> tuple[Any, Any]:
    if cfg.scheme == "none":
        return grads, residual

    def per_tensor(g, r):
        acc = g.astype(jnp.float32) + r
        if cfg.scheme == "topk":
            sent = _topk_tensor(acc, cfg.topk_frac)
        elif cfg.scheme == "int8":
            sent = _int8_tensor(acc)
        else:
            raise ValueError(cfg.scheme)
        return sent.astype(g.dtype), acc - sent

    out = jax.tree.map(per_tensor, grads, residual)
    treedef = jax.tree.structure(grads)
    leaves = jax.tree.leaves(out, is_leaf=lambda x: isinstance(x, tuple))
    sent = jax.tree.unflatten(treedef, [l[0] for l in leaves])
    resid = jax.tree.unflatten(treedef, [l[1] for l in leaves])
    return sent, resid


def wire_bytes(cfg: CompressionConfig, param_count: int,
               n_workers: int = 2) -> float:
    """Bytes per worker per iteration after compression (ring all-reduce)."""
    ring = 2.0 * (n_workers - 1) / n_workers
    if cfg.scheme == "none":
        return ring * param_count * 4.0
    if cfg.scheme == "int8":
        return ring * param_count * 1.0
    if cfg.scheme == "topk":
        # value + index per surviving entry
        return ring * param_count * cfg.topk_frac * 8.0
    raise ValueError(cfg.scheme)
