"""Job compatibility (paper §2.2 Challenge 1, §4.6; concept from [66, 67]).

Two jobs sharing a link are *compatible* when the comm phase of one fits in
the compute phase of the other.  The score below follows Cassini's geometric
definition: place each job's comm window on the circle of its period, sweep
relative offsets, and measure the best-case non-overlap of comm time.

score = 1  -> a relative shift exists where comm phases never collide;
score -> 0 -> comm phases must overlap almost entirely no matter the shift.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.workload.comm_model import CommProfile, GBPS


def _comm_windows(p: CommProfile, link_rate: float) -> tuple[np.ndarray, float]:
    """[(start, end)] of comm windows within one iteration, plus the period."""
    t = 0.0
    wins = []
    for c, b in zip(p.compute_s, p.comm_bytes):
        t += c
        dur = b / link_rate
        wins.append((t, t + dur))
        t += dur
    return np.asarray(wins), t


def _overlap_on_circle(wa: np.ndarray, per_a: float, wb: np.ndarray,
                       per_b: float, offset: float, horizon: float) -> float:
    """Total seconds both jobs communicate simultaneously in [0, horizon)."""
    grid = np.linspace(0.0, horizon, 4096, endpoint=False)

    def busy(wins, per, off):
        ph = np.mod(grid - off, per)
        out = np.zeros_like(grid, dtype=bool)
        for (s, e) in wins:
            out |= (ph >= s) & (ph < e)
        return out

    a = busy(wa, per_a, 0.0)
    b = busy(wb, per_b, offset)
    both = np.logical_and(a, b).mean() * horizon
    tot_b = b.mean() * horizon
    return both, tot_b


def compatibility_score(a: CommProfile, b: CommProfile,
                        link_rate: float = 50 * GBPS,
                        n_offsets: int = 64) -> float:
    """max over relative offsets of (1 - overlapped comm fraction)."""
    wa, pa = _comm_windows(a, link_rate)
    wb, pb = _comm_windows(b, link_rate)
    horizon = max(pa, pb) * 4
    best = 0.0
    for off in np.linspace(0.0, pb, n_offsets, endpoint=False):
        both, tot_b = _overlap_on_circle(wa, pa, wb, pb, off, horizon)
        frac = 1.0 - (both / tot_b if tot_b > 0 else 0.0)
        best = max(best, frac)
    return float(best)


def best_offsets(profiles: list[CommProfile],
                 link_rate: float = 50 * GBPS,
                 n_offsets: int = 32) -> np.ndarray:
    """Brute-force joint offsets minimizing pairwise comm overlap (used by
    the Cassini baseline on a single shared link).  Job 0 is the reference.
    Exponential in job count; fine for the paper's 2-3-job experiments, and
    greedy beyond that."""
    j = len(profiles)
    wins = []
    pers = []
    for p in profiles:
        w, per = _comm_windows(p, link_rate)
        wins.append(w)
        pers.append(per)
    horizon = max(pers) * 4

    if j <= 3:
        cands = [np.linspace(0.0, pers[i], n_offsets, endpoint=False)
                 for i in range(j)]
        best, best_off = None, np.zeros((j,))
        for combo in itertools.product(*[cands[i] for i in range(1, j)]):
            offs = np.asarray((0.0,) + combo)
            tot = 0.0
            for x in range(j):
                for y in range(x + 1, j):
                    both, _ = _overlap_on_circle(
                        wins[x], pers[x], wins[y], pers[y],
                        offs[y] - offs[x], horizon)
                    tot += both
            if best is None or tot < best:
                best, best_off = tot, offs
        return best_off

    # greedy: place jobs one at a time at the offset minimizing added overlap
    offs = np.zeros((j,))
    for i in range(1, j):
        best, arg = None, 0.0
        for off in np.linspace(0.0, pers[i], n_offsets, endpoint=False):
            tot = 0.0
            for x in range(i):
                both, _ = _overlap_on_circle(wins[x], pers[x], wins[i],
                                             pers[i], off - offs[x], horizon)
                tot += both
            if best is None or tot < best:
                best, arg = tot, off
        offs[i] = arg
    return offs
