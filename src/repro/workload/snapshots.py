"""Table 2 snapshots — job combinations competing for bandwidth (§4.4).

Each snapshot places two jobs on the hierarchical (two-tier) topology of
Figure 6(b); the paper generated them from Cassini's snapshot trace with
varying models, parallelization strategies, worker counts, and resulting
compatibility scores.
"""
from __future__ import annotations

import dataclasses

from repro.netsim.topology import Topology, two_tier
from repro.workload.comm_model import CommProfile, profile_for


@dataclasses.dataclass(frozen=True)
class Snapshot:
    name: str
    profiles: tuple[CommProfile, ...]
    topo: Topology
    compat_paper: float   # the compatibility score Table 2 reports


def table2_snapshots(sockets_per_job: int = 2) -> list[Snapshot]:
    def topo2():
        # two jobs crossing leaf0 -> leaf1 and leaf2 -> leaf1: they share
        # the down-link of leaf 1 (the contended 50 Gbps hop).
        return two_tier([(0, 1), (2, 1)], n_leaves=4,
                        sockets_per_job=sockets_per_job)

    return [
        Snapshot("wrn101_vs_vgg16",
                 (profile_for("wideresnet101"), profile_for("vgg16")),
                 topo2(), 0.88),
        Snapshot("camembert_vs_roberta",
                 (profile_for("camembert"), profile_for("roberta")),
                 topo2(), 0.9),
        Snapshot("gpt1_vs_gpt1",
                 (profile_for("gpt1"), profile_for("gpt1")),
                 topo2(), 1.0),
        Snapshot("gpt2_vs_gpt3hybrid",
                 (profile_for("gpt2"), profile_for("gpt3_hybrid")),
                 topo2(), 1.0),
    ]
