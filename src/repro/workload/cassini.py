"""Cassini baseline [66] — centralized time-shift scheduling.

Cassini (NSDI'24) interleaves jobs by (1) solving for per-job time shifts on
an *affinity graph* (jobs x shared links) so that comm phases dovetail, and
(2) running an end-host agent that re-aligns any job drifting from its
intended schedule (by delaying its next comm phase to the assigned slot).

Faithful properties reproduced here (paper §2.2, §4.5-4.7):
  * works when the affinity graph is a tree and jobs are compatible;
  * requires a loop-free affinity graph (Theorem 1 of [66]) — on the
    circular-dependency triangle (Figure 2) it has no consistent solution,
    so `cassini_schedule` falls back to zero shifts there (and the agent's
    re-alignment then *hurts*, as the paper observes);
  * the agent's skip/delay behavior under stragglers is what degrades its
    tail iteration times for straggle probability > 10%.
"""
from __future__ import annotations

import numpy as np

from repro.netsim.engine import CassiniSchedule
from repro.netsim.topology import Topology
from repro.workload.comm_model import CommProfile, GBPS
from repro.workload.compat import best_offsets


def _affinity_graph(topo: Topology) -> tuple[list[tuple[int, int]], bool]:
    """Edges (job_a, job_b) for each shared link; plus has_cycle flag."""
    share: dict[int, set[int]] = {}
    for n in range(topo.n_flows):
        j = int(topo.flow_to_job[n])
        for l in topo.hops[n]:
            if l >= 0:
                share.setdefault(int(l), set()).add(j)
    edges = set()
    for jobs in share.values():
        jobs = sorted(jobs)
        for i in range(len(jobs)):
            for k in range(i + 1, len(jobs)):
                edges.add((jobs[i], jobs[k]))
    edges = sorted(edges)
    # cycle detection via union-find
    parent = list(range(topo.n_jobs))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    has_cycle = False
    for a, b in edges:
        ra, rb = find(a), find(b)
        if ra == rb:
            has_cycle = True
        else:
            parent[ra] = rb
    return edges, has_cycle


def cassini_schedule(topo: Topology, profiles: list[CommProfile],
                     link_rate: float = 50 * GBPS,
                     eps_frac: float = 0.1,
                     period_slack: float = 1.06) -> tuple[CassiniSchedule, bool]:
    """Compute the centralized schedule. Returns (schedule, feasible).

    ``period_slack`` pads the isolation iteration time the way Cassini's
    "expected optimal iteration time" absorbs protocol overheads (ramp-up,
    queueing): without it, small per-iteration drift forces a full-slot
    re-alignment every cycle. ``eps_frac`` is the agent's tolerance as a
    fraction of the period (straggler sleeps of 5-10% exceed it — the
    paper's >10%-straggle failure mode).

    feasible=False on cyclic affinity graphs (Figure 2): shifts fall back to
    zero and the agent still enforces them — reproducing Cassini's failure
    mode on circular dependencies.
    """
    periods = np.asarray([p.iso_iter_time(link_rate) for p in profiles]) \
        * period_slack
    eps = float(eps_frac * periods.min())
    _, has_cycle = _affinity_graph(topo)
    if has_cycle:
        return CassiniSchedule(offset=np.zeros_like(periods),
                               period=periods, eps=eps), False
    offsets = best_offsets(profiles, link_rate)
    return CassiniSchedule(offset=offsets, period=periods, eps=eps), True
