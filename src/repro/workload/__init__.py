"""workload — DNN training jobs as network traffic sources.

Converts model/parallelization descriptions into the per-iteration
(compute_s, comm_bytes) phase programs the netsim engine runs, plus the
paper's baseline machinery: compatibility scores, Cassini's centralized
time-shift scheduler, and the Table-2 snapshot traces.
"""

from repro.workload.comm_model import (
    PAPER_MODELS,
    CommProfile,
    dp_allreduce_bytes,
    profile_for,
    jobspec_from_profiles,
)
from repro.workload.compat import compatibility_score, best_offsets
from repro.workload.cassini import cassini_schedule
from repro.workload.snapshots import table2_snapshots

__all__ = [
    "PAPER_MODELS", "CommProfile", "dp_allreduce_bytes", "profile_for",
    "jobspec_from_profiles", "compatibility_score", "best_offsets",
    "cassini_schedule", "table2_snapshots",
]
