"""Communication/compute models of DNN training jobs.

A job is, on the wire, a periodic phase program: per training iteration, one
or more (compute_s, comm_bytes) sub-phases.  Data-parallel jobs are on/off
(one gradient all-reduce per iteration); hybrid DP/PP/TP jobs have multiple
peaks (paper §3.5: Algorithm 1's gap heuristic is designed exactly for this).

Two profile sources:
  * PAPER_MODELS — the 7 models of Table 1, with parameter counts from their
    public papers and per-GPU compute times scaled from an A100 roofline, so
    the reproduction benchmarks (Figs 7-17) train "the paper's" jobs;
  * profile_from_arch — any of the 10 assigned architectures (configs/),
    using exact parameter counts from the sharded model and a TPU-v5e
    roofline for compute times (wired up by repro.cluster).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.netsim.engine import JobSpec

GBPS = 1e9 / 8.0


@dataclasses.dataclass(frozen=True)
class CommProfile:
    """One job's per-iteration traffic description."""

    name: str
    compute_s: tuple[float, ...]      # per sub-phase compute durations
    comm_bytes: tuple[float, ...]     # per sub-phase network bytes (per NIC)
    parallelism: str = "data"

    @property
    def total_bytes(self) -> float:
        return float(sum(self.comm_bytes))

    @property
    def total_compute(self) -> float:
        return float(sum(self.compute_s))

    def iso_iter_time(self, link_bytes_per_s: float = 50 * GBPS) -> float:
        """Isolation iteration time: compute + exposed comm at line rate."""
        return self.total_compute + self.total_bytes / link_bytes_per_s

    def scaled(self, factor: float) -> "CommProfile":
        """Uniformly scale the whole program (sweep workloads)."""
        return dataclasses.replace(
            self,
            compute_s=tuple(c * factor for c in self.compute_s),
            comm_bytes=tuple(b * factor for b in self.comm_bytes),
        )

    def compute_scaled(self, factor: float) -> "CommProfile":
        """Scale only the compute phases (comm bytes fixed) — varies the
        compute:comm duty ratio, i.e. the partial-compatibility axis.  The
        result keeps the phase *structure*, so a plan sweeping this factor
        changes only traced workload values and stays one compile group."""
        return dataclasses.replace(
            self, compute_s=tuple(c * factor for c in self.compute_s))


def dp_allreduce_bytes(param_count: float, n_workers: int,
                       bytes_per_param: float = 4.0) -> float:
    """Ring all-reduce bytes each worker sends per iteration:
    2 * (k-1)/k * model_bytes."""
    k = max(n_workers, 2)
    return 2.0 * (k - 1) / k * param_count * bytes_per_param


def _dp(name: str, params_m: float, compute_ms: float,
        n_workers: int = 2) -> CommProfile:
    return CommProfile(
        name=name,
        compute_s=(compute_ms * 1e-3,),
        comm_bytes=(dp_allreduce_bytes(params_m * 1e6, n_workers),),
        parallelism="data",
    )


# ---------------------------------------------------------------------------
# Table 1 models. Parameter counts from the cited papers; compute times are
# per-iteration GPU phases at the paper's batch sizes on an A100, scaled so
# that the comm:compute duty ratios land in the regime the paper reports
# (compatible pairs fit one comm phase inside the other's compute phase).
# ---------------------------------------------------------------------------

PAPER_MODELS: dict[str, CommProfile] = {
    # VGG16: 138M params, batch 1400/GPU -> long compute, huge gradients.
    "vgg16": _dp("vgg16", 138.0, 220.0),
    # WideResNet101: 126.9M params, batch 800.
    "wideresnet101": _dp("wideresnet101", 126.9, 180.0),
    # RoBERTa-large: 355M params, batch 28.
    "roberta": _dp("roberta", 355.0, 260.0),
    # CamemBERT-base: 110M params, batch 28.
    "camembert": _dp("camembert", 110.0, 90.0),
    # GPT-1: 117M params, batch 31.
    "gpt1": _dp("gpt1", 117.0, 100.0),
    # GPT-2 (124M), batch 5-44; the convergence benchmarks' workhorse.
    # compute at batch ~30: self-compatible pair (Table 2 lists compat 1.0).
    "gpt2": _dp("gpt2", 124.0, 100.0),
    # GPT-3 scaled-down hybrid DP/PP/MP job (paper trains a 4-server slice,
    # batch 3): pipeline stages produce a multi-peak pattern: three activation
    # bursts between compute chunks, then the gradient all-reduce.
    "gpt3_hybrid": CommProfile(
        name="gpt3_hybrid",
        compute_s=(40e-3, 25e-3, 25e-3, 20e-3),
        comm_bytes=(30e6, 30e6, 30e6, 420e6),
        parallelism="hybrid",
    ),
}


def profile_for(name: str) -> CommProfile:
    try:
        return PAPER_MODELS[name]
    except KeyError as e:
        raise ValueError(f"unknown paper model {name!r}; "
                         f"choose from {sorted(PAPER_MODELS)}") from e


def jobspec_from_profiles(profiles: list[CommProfile],
                          start_offset=None, straggle_prob=None,
                          link_bytes_per_s: float = 50 * GBPS) -> JobSpec:
    """Pack heterogeneous phase programs into the engine's JobSpec arrays."""
    j = len(profiles)
    p = max(len(pr.compute_s) for pr in profiles)
    compute = np.zeros((j, p))
    comm = np.zeros((j, p))
    n_phases = np.zeros((j,), np.int32)
    iso = np.zeros((j,))
    for i, pr in enumerate(profiles):
        k = len(pr.compute_s)
        compute[i, :k] = pr.compute_s
        comm[i, :k] = pr.comm_bytes
        n_phases[i] = k
        iso[i] = pr.iso_iter_time(link_bytes_per_s)
    return JobSpec(
        compute=compute,
        comm_bytes=comm,
        n_phases=n_phases,
        start_offset=(np.zeros((j,)) if start_offset is None
                      else np.asarray(start_offset, np.float64)),
        straggle_prob=(np.zeros((j,)) if straggle_prob is None
                       else np.asarray(straggle_prob, np.float64)),
        iso_iter_time=iso,
    )
