"""recurrentgemma-2b [arXiv:2402.19427]: Griffin — RG-LRU blocks with local
attention every third block (pattern rec,rec,attn_local; window 2048)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        d_model=2560, n_layers=26, n_heads=10, n_kv_heads=1, d_head=256,
        d_ff=7680, vocab=256_000,
        block_pattern=("rec", "rec", "attn_local"),
        window=2048,
        embed_scale=True, tie_embeddings=True,
        conv_width=4,
        family="hybrid", subquadratic=True,
    ).validate()
