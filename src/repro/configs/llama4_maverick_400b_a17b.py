"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4 family; unverified tier]:
48L, 128 routed experts top-1 + 1 shared, MoE on alternating layers."""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        d_model=5120, n_layers=48, n_heads=40, n_kv_heads=8, d_head=128,
        d_ff=8192, vocab=202_048,
        block_pattern=("attn", "attn"),
        ffn_pattern=("dense", "moe"),     # MoE interleaved every other layer
        moe=MoEConfig(n_experts=128, top_k=1, n_shared=1, d_expert=8192,
                      every_k_layers=2),
        rope_theta=500_000.0,
        family="moe",
    ).validate()
