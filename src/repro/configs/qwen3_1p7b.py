"""qwen3-1.7b [hf:Qwen/Qwen3 family]: GQA + qk-norm, tied embeddings."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b",
        d_model=2048, n_layers=28, n_heads=16, n_kv_heads=8, d_head=128,
        d_ff=6144, vocab=151_936,
        block_pattern=("attn",),
        qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=True,
        family="dense",
    ).validate()
