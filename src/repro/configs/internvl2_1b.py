"""internvl2-1b [arXiv:2404.16821]: Qwen2-0.5B LM backbone + InternViT
frontend stub (input_specs provides precomputed patch embeddings that a
learned projector maps into the LM width)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        d_model=896, n_layers=24, n_heads=14, n_kv_heads=2, d_head=64,
        d_ff=4864, vocab=151_655,
        block_pattern=("attn",),
        rope_theta=1_000_000.0, tie_embeddings=True,
        vision_tokens=256, vit_dim=1024,
        family="vlm",
    ).validate()
