"""deepseek-moe-16b [arXiv:2401.06066]: fine-grained MoE, 2 shared + 64
routed top-6 experts, first layer dense."""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        d_model=2048, n_layers=28, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=1408, vocab=102_400,
        block_pattern=("attn",),
        moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
        first_k_dense=1, dense_d_ff=10_944,
        rope_theta=10_000.0,
        family="moe",
    ).validate()
