"""olmo-1b [arXiv:2402.00838]: non-parametric LayerNorm, tied embeddings."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        d_model=2048, n_layers=16, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=8192, vocab=50_304,
        block_pattern=("attn",),
        nonparam_norm=True, tie_embeddings=True,
        family="dense",
    ).validate()
