"""xlstm-125m [arXiv:2405.04517]: mLSTM + sLSTM blocks (3:1 ratio), no
separate FFN (d_ff=0; width lives in the block projections)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        d_model=768, n_layers=12, n_heads=4, n_kv_heads=4, d_head=192,
        d_ff=0, vocab=50_304,
        block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        tie_embeddings=True,
        conv_width=4,
        family="ssm", subquadratic=True,
    ).validate()
