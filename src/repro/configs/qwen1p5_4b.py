"""qwen1.5-4b [hf:Qwen/Qwen1.5 family]: QKV bias, full MHA (kv=heads)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        d_model=2560, n_layers=40, n_heads=20, n_kv_heads=20, d_head=128,
        d_ff=6912, vocab=151_936,
        block_pattern=("attn",),
        qkv_bias=True, rope_theta=5_000_000.0,
        family="dense",
    ).validate()
