"""seamless-m4t-medium [arXiv:2308.11596]: encoder-decoder; the speech
frontend is a stub (input_specs provides precomputed frame embeddings)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        d_model=1024, n_layers=12, n_heads=16, n_kv_heads=16, d_head=64,
        d_ff=4096, vocab=256_206,
        block_pattern=("attn",),
        enc_layers=12, enc_seq_divisor=4,
        family="audio",
    ).validate()
