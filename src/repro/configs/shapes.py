"""Assigned input shapes (identical set for every LM-family architecture).

  train_4k     seq 4096,    global batch 256  -> train_step
  prefill_32k  seq 32768,   global batch 32   -> serve_step (prefill)
  decode_32k   seq 32768,   global batch 128  -> serve_step (1 token, KV cache)
  long_500k    seq 524288,  global batch 1    -> serve_step (decode; only for
               sub-quadratic archs — skips recorded per DESIGN.md §5)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
