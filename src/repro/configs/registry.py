"""Architecture registry: ``--arch <id>`` resolution + shape applicability."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig
from repro.configs.shapes import SHAPES, ShapeSpec

# arch id -> module name under repro.configs
_MODULES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "xlstm-125m": "xlstm_125m",
    "qwen3-1.7b": "qwen3_1p7b",
    "qwen1.5-4b": "qwen1p5_4b",
    "gemma2-27b": "gemma2_27b",
    "olmo-1b": "olmo_1b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "internvl2-1b": "internvl2_1b",
}

ARCH_IDS: tuple[str, ...] = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    try:
        mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    except KeyError as e:
        raise ValueError(f"unknown arch {arch!r}; choose from {ARCH_IDS}") from e
    return mod.config()


def shape_skip_reason(cfg: ModelConfig, shape: ShapeSpec | str) -> str | None:
    """None if the (arch, shape) cell runs; otherwise the documented skip.

    Per the brief + DESIGN.md §5: long_500k needs sub-quadratic attention —
    it runs only for the SSM/hybrid archs and is skipped for pure
    full-attention architectures.
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("skip: long_500k requires sub-quadratic attention; "
                f"{cfg.name} has quadratic global-attention layers")
    return None
