"""gemma2-27b [arXiv:2408.00118]: local+global alternating attention,
attn/final logit softcaps, pre+post norm sandwich, window 4096."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        d_model=4608, n_layers=46, n_heads=32, n_kv_heads=16, d_head=128,
        d_ff=36_864, vocab=256_000,
        block_pattern=("attn_local", "attn"),
        window=4096,
        attn_softcap=50.0, logit_softcap=30.0,
        post_norm=True, embed_scale=True, tie_embeddings=True,
        family="dense",
    ).validate()
