"""configs — assigned architectures (exact public configs) and input shapes."""

from repro.configs.registry import ARCH_IDS, get_config, shape_skip_reason
from repro.configs.shapes import SHAPES, ShapeSpec

__all__ = ["ARCH_IDS", "get_config", "shape_skip_reason", "SHAPES", "ShapeSpec"]
