import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first initialization).

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell and both production meshes,
lower + compile the real train/prefill/decode step with ShapeDtypeStruct
stand-ins (no allocation), then record:

  * memory_analysis()  — per-device bytes (proves the cell fits),
  * cost_analysis()    — FLOPs / bytes for the roofline,
  * the collective schedule parsed from the compiled HLO.

Roofline extraction additionally lowers unrolled L=1 / L=2 variants to solve
cost(L) = stem + L*body exactly (XLA counts a scanned while body once — see
DESIGN.md §4); that happens in repro.roofline.analysis, driven from here.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_skip_reason
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.models.config import ModelConfig
from repro.roofline.hlo import collective_bytes_from_text
from repro.train import (
    ShardingRules,
    TrainHyper,
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    param_pspecs,
)
from repro.train.sharding import auto_pspec

SERVE_DTYPE = jnp.bfloat16

# Per-arch training hyper-parameters for the production cells: bf16 params
# everywhere (mixed precision); the large models additionally use bf16
# optimizer moments (DeepSeek-V3-style) and gradient accumulation so the
# activation carry fits 16 GB/chip. Recorded in EXPERIMENTS.md §Dry-run.
from repro.optim import AdamWConfig  # noqa: E402

_BF16_OPT = AdamWConfig(state_dtype="bfloat16")
_DEFAULT_HYPER = TrainHyper(param_dtype="bfloat16", microbatches=2)
TRAIN_HYPER_OVERRIDES = {
    "llama4-maverick-400b-a17b": TrainHyper(param_dtype="bfloat16",
                                            opt=_BF16_OPT, microbatches=8),
    "gemma2-27b": TrainHyper(param_dtype="bfloat16", opt=_BF16_OPT,
                             microbatches=8),
    "deepseek-moe-16b": TrainHyper(param_dtype="bfloat16", opt=_BF16_OPT,
                                   microbatches=8),
    "recurrentgemma-2b": TrainHyper(param_dtype="bfloat16", microbatches=4),
    "qwen1.5-4b": TrainHyper(param_dtype="bfloat16", microbatches=4),
    "internvl2-1b": TrainHyper(param_dtype="bfloat16", microbatches=4),
}
# big models also shard weights/optimizer over the data axes when training
FSDP_TRAIN_ARCHS = {"llama4-maverick-400b-a17b", "gemma2-27b",
                    "deepseek-moe-16b"}


def train_hyper_for(arch: str) -> TrainHyper:
    return TRAIN_HYPER_OVERRIDES.get(arch, _DEFAULT_HYPER)


def _data_axes(mesh):
    return tuple(a for a in mesh.axis_names if a != "model")


# Hillclimb winners adopted as production defaults (EXPERIMENTS.md §Perf):
#  * sequence-parallel attention scores for archs whose head count doesn't
#    divide the 16-way model axis (A1: 7.5x on the memory term) — applied
#    to full-sequence cells only;
#  * sequence-sharded KV caches for decode cells (B2: collective term
#    -2467x) — context-parallel decode;
#  * FSDP weight sharding for the MoE serving cells (fits 16 GB/chip).
SEQ_SHARD_ARCHS = {"qwen1.5-4b", "llama4-maverick-400b-a17b",
                   "internvl2-1b"}
FSDP_SERVE_ARCHS = {"llama4-maverick-400b-a17b", "deepseek-moe-16b"}


def default_rules_for(arch: str, shape_kind: str, mesh) -> ShardingRules:
    dp = _data_axes(mesh)
    if shape_kind == "decode":
        return ShardingRules(data_axes=dp, decode_cache_seq_shard=True,
                             fsdp=arch in FSDP_SERVE_ARCHS)
    if shape_kind == "prefill":
        return ShardingRules(data_axes=dp, fsdp=arch in FSDP_SERVE_ARCHS)
    return ShardingRules(data_axes=dp, fsdp=arch in FSDP_TRAIN_ARCHS)


def _batch_specs(cfg: ModelConfig, b: int, s: int, mesh, microbatches: int = 1,
                 rules=None):
    """ShapeDtypeStructs + PartitionSpecs for one batch. With gradient
    accumulation the leading microbatch axis is unsharded: [mb, b/mb, ...]."""
    dp = rules.data_axes if rules is not None else _data_axes(mesh)
    dsize = 1
    for a in dp:
        dsize *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    mb = microbatches
    bb = b // mb
    bspec = dp if bb % dsize == 0 else None
    lead = (mb,) if mb > 1 else ()
    lspec = (None,) if mb > 1 else ()

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(lead + shape, dtype)

    batch = {"tokens": sds((bb, s), jnp.int32)}
    spec = {"tokens": P(*lspec, bspec, None)}
    if cfg.family == "audio":
        t_enc = max(s // cfg.enc_seq_divisor, 8)
        batch["frames"] = sds((bb, t_enc, cfg.d_model), jnp.float32)
        spec["frames"] = P(*lspec, bspec, None, None)
    if cfg.family == "vlm":
        batch["patches"] = sds((bb, cfg.vision_tokens, cfg.vit_dim),
                               jnp.float32)
        spec["patches"] = P(*lspec, bspec, None, None)
        # vision tokens prepend to the sequence; keep total = s
        batch["tokens"] = sds((bb, s - cfg.vision_tokens), jnp.int32)
    return batch, spec


def _shardings(tree_spec, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_spec,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(cfg: ModelConfig, shape_name: str, mesh,
               rules: ShardingRules | None = None, unroll: bool = False,
               hyper_override: TrainHyper | None = None):
    """Returns (fn, arg_shapes, in_shardings, out_shardings, donate)."""
    import dataclasses as _dc
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    rules = rules or ShardingRules(data_axes=_data_axes(mesh))

    if shape.kind == "train":
        hyper = hyper_override or train_hyper_for(cfg.name)
        if unroll:
            hyper = _dc.replace(hyper, unroll=True)
        step = make_train_step(cfg, hyper)
        state_shape = jax.eval_shape(
            lambda k: init_train_state(cfg, hyper, k),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        pspecs = param_pspecs(cfg, state_shape.params, mesh, rules)
        state_spec = state_shape._replace(
            params=pspecs,
            opt=state_shape.opt._replace(step=P(), m=pspecs, v=pspecs),
            residual=None, step=P())
        batch, bspec = _batch_specs(cfg, b, s, mesh,
                                    microbatches=hyper.microbatches,
                                    rules=rules)
        state_sh = _shardings(jax.tree.map(lambda x: x, state_spec), mesh)
        in_sh = (state_sh, _shardings(bspec, mesh))
        metrics_sh = jax.eval_shape(step, state_shape, batch)[1]
        out_sh = (state_sh, jax.tree.map(
            lambda _: NamedSharding(mesh, P()), metrics_sh))
        return step, (state_shape, batch), in_sh, out_sh, (0,)

    # --- serving cells use bf16 params ---
    params_shape = jax.eval_shape(lambda k: api.init_params(cfg, k),
                                  jax.ShapeDtypeStruct((2,), jnp.uint32))
    params_shape = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, SERVE_DTYPE), params_shape)
    pspecs = param_pspecs(cfg, params_shape, mesh, rules)
    psh = _shardings(pspecs, mesh)

    stacked = {"groups", "enc", "dec", "self", "cross"}

    def cache_pspecs(cache_shape):
        def one(path, leaf):
            names = [str(getattr(e, "key", "")) for e in path]
            is_stacked = any(n in stacked for n in names)
            nd = len(leaf.shape) - (1 if is_stacked else 0)
            if (rules is not None and rules.decode_cache_seq_shard
                    and nd == 4 and names[-1] in ("k", "v")):
                # [B, S, K, dh]: sequence-sharded KV (context parallelism),
                # axis by axis only where the dim divides the mesh axes
                sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
                dsize = 1
                for a in rules.data_axes:
                    dsize *= sizes.get(a, 1)
                shp = leaf.shape[1:] if is_stacked else leaf.shape
                bspec = rules.data_axes if shp[0] % dsize == 0 else None
                sspec = "model" if shp[1] % sizes.get("model", 1) == 0 \
                    else None
                spec = (bspec, sspec, None, None)
                if is_stacked:
                    spec = (None,) + spec
                return P(*spec)
            return auto_pspec(leaf.shape, mesh, rules, stacked=is_stacked)
        return jax.tree_util.tree_map_with_path(one, cache_shape)

    if shape.kind == "prefill":
        step = make_prefill_step(cfg, max_len=s, unroll=unroll)
        batch, bspec = _batch_specs(cfg, b, s, mesh)
        out_shape = jax.eval_shape(step, params_shape, batch)
        out_sh = (NamedSharding(mesh, P(None)),
                  _shardings(cache_pspecs(out_shape[1]), mesh))
        return (step, (params_shape, batch),
                (psh, _shardings(bspec, mesh)), out_sh, ())

    # decode: one new token against a seq_len cache
    step = make_decode_step(cfg, unroll=unroll)
    cache_shape = jax.eval_shape(
        lambda: api.init_cache(cfg, b, s, SERVE_DTYPE))
    csh = _shardings(cache_pspecs(cache_shape), mesh)
    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    index = jax.ShapeDtypeStruct((), jnp.int32)
    in_sh = (psh, csh, NamedSharding(mesh, P(None)), NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, P(None)), csh)
    return (step, (params_shape, cache_shape, token, index), in_sh, out_sh,
            (1,))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules: ShardingRules | None = None, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    skip = shape_skip_reason(cfg, shape_name)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    shape_kind = SHAPES[shape_name].kind
    if rules is None:
        rules = default_rules_for(arch, shape_kind, mesh)
    from repro.models import attention as _attn
    prev_seq = _attn.SEQ_SHARD_AXIS
    if arch in SEQ_SHARD_ARCHS and shape_kind in ("train", "prefill"):
        _attn.SEQ_SHARD_AXIS = "model"
    try:
        fn, args, in_sh, out_sh, donate = build_cell(cfg, shape_name, mesh,
                                                     rules)
        with jax.set_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        txt = compiled.as_text()
        colls = collective_bytes_from_text(txt)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops_per_device": ca.get("flops", 0.0),
            "bytes_accessed_per_device": ca.get("bytes accessed", 0.0),
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.temp_size_in_bytes),
            "collectives": colls,
        })
        if verbose:
            print(f"[{rec['mesh']}] {arch} x {shape_name}: "
                  f"args={ma.argument_size_in_bytes/2**30:.2f}GiB "
                  f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
                  f"flops/dev={ca.get('flops', 0):.3g} "
                  f"colls={ {k: round(v/2**20, 1) for k, v in colls['bytes_by_kind'].items()} }MiB "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[{rec['mesh']}] {arch} x {shape_name}: FAILED {rec['error']}")
    finally:
        _attn.SEQ_SHARD_AXIS = prev_seq
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                records.append(run_cell(arch, shape, mp))
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), {n_err} failed")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print("wrote", args.out)
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
