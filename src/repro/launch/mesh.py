"""Production mesh definition.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before *any* jax
initialization, while smoke tests and benchmarks must see 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = ("data", "model") — 256 chips (v5e pod).
    Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that carry the global batch."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Tiny mesh for CI-scale sharding tests (requires >= n_data*n_model
    host devices, e.g. via --xla_force_host_platform_device_count=8)."""
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
