"""Training launcher.

On this CPU container the ``smoke`` preset trains a reduced same-family
config end-to-end (real data pipeline, AdamW, checkpointing, restart); the
``full`` preset builds the production sharded step for the real config (the
path the multi-pod dry-run exercises) — launchable unchanged on a pod.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 200
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --resume ...
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data import DataConfig, make_batch_iterator
from repro.checkpoint import CheckpointManager
from repro.train import TrainHyper, init_train_state, make_train_step


def train(arch: str, steps: int = 100, seq_len: int = 128, batch: int = 8,
          ckpt_dir: str | None = None, resume: bool = False,
          ckpt_every: int = 50, preset: str = "smoke", seed: int = 0,
          compression: str = "none", log_every: int = 10) -> dict:
    cfg = get_config(arch)
    if preset == "smoke":
        cfg = cfg.scaled_down()
    from repro.optim import CompressionConfig
    hyper = TrainHyper(warmup=max(steps // 20, 5), total_steps=steps,
                       compression=CompressionConfig(scheme=compression))
    state = init_train_state(cfg, hyper, jax.random.PRNGKey(seed))

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if resume and mgr and mgr.latest_step() is not None:
        state = mgr.restore(state)
        start_step = int(state.step)
        print(f"resumed from step {start_step}")

    dc = DataConfig(vocab=cfg.vocab, seq_len=seq_len, global_batch=batch,
                    seed=seed,
                    frames=(seq_len // cfg.enc_seq_divisor
                            if cfg.family == "audio" else 0),
                    frame_dim=cfg.d_model if cfg.family == "audio" else 0,
                    vision_tokens=cfg.vision_tokens,
                    vit_dim=cfg.vit_dim)
    it = make_batch_iterator(dc, start_step=start_step)
    step_fn = jax.jit(make_train_step(cfg, hyper), donate_argnums=0)

    losses = []
    t0 = time.time()
    for i in range(start_step, steps):
        state, metrics = step_fn(state, next(it))
        losses.append(float(metrics["loss"]))
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time() - t0) / max(i - start_step + 1, 1):.2f}"
                  f" s/step)")
        if mgr and (i + 1) % ckpt_every == 0:
            mgr.save(i + 1, state)
    if mgr:
        mgr.save(steps, state, blocking=True)
    first = float(np.mean(losses[:10])) if len(losses) >= 10 else losses[0]
    last = float(np.mean(losses[-10:]))
    return {"first_loss": first, "last_loss": last, "steps": len(losses)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="olmo-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--preset", choices=("smoke", "full"), default="smoke")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compression", choices=("none", "topk", "int8"),
                    default="none")
    args = ap.parse_args()
    out = train(args.arch, steps=args.steps, seq_len=args.seq_len,
                batch=args.batch, ckpt_dir=args.ckpt_dir,
                resume=args.resume, preset=args.preset,
                compression=args.compression)
    print(f"loss {out['first_loss']:.3f} -> {out['last_loss']:.3f} "
          f"over {out['steps']} steps")


if __name__ == "__main__":
    main()
