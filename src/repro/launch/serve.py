"""Serving launcher: batched prefill + greedy decode on any assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import api
from repro.train import make_decode_step, make_prefill_step


def serve(arch: str, batch: int = 4, prompt_len: int = 32, new_tokens: int = 16,
          preset: str = "smoke", seed: int = 0) -> dict:
    cfg = get_config(arch)
    if preset == "smoke":
        cfg = cfg.scaled_down()
    key = jax.random.PRNGKey(seed)
    params = api.init_params(cfg, key)
    max_len = prompt_len + new_tokens + 8

    req = {"tokens": jax.random.randint(key, (batch, prompt_len), 0,
                                        cfg.vocab)}
    if cfg.family == "audio":
        req["frames"] = jax.random.normal(
            key, (batch, max(prompt_len // cfg.enc_seq_divisor, 4),
                  cfg.d_model))
    if cfg.family == "vlm":
        req["patches"] = jax.random.normal(
            key, (batch, cfg.vision_tokens, cfg.vit_dim))

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=1)

    t0 = time.time()
    tok, cache = prefill(params, req)
    tok.block_until_ready()
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    pos0 = prompt_len + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    for i in range(new_tokens - 1):
        tok, cache = decode(params, cache, tok,
                            jnp.asarray(pos0 + i, jnp.int32))
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t0

    gen = jnp.stack(out, axis=1)
    return {
        "generated": gen,
        "prefill_s": t_prefill,
        "decode_tok_per_s": batch * (new_tokens - 1) / max(t_decode, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--preset", choices=("smoke", "full"), default="smoke")
    args = ap.parse_args()
    out = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                new_tokens=args.new, preset=args.preset)
    print("generated ids:\n", out["generated"])
    print(f"prefill {out['prefill_s'] * 1e3:.1f} ms; "
          f"decode {out['decode_tok_per_s']:.1f} tok/s (CPU smoke)")


if __name__ == "__main__":
    main()
