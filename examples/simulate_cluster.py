"""End-to-end driver (the paper's kind): the framework's own training jobs
compete for a shared 50 Gbps DCN link; MLTCP (MLQCN) vs default DCQCN.

    PYTHONPATH=src python examples/simulate_cluster.py

Each job's traffic profile (per-iteration bytes = its cross-pod gradient
all-reduce; compute gap = its roofline step time) is derived from the real
architecture configs — the `total_bytes` Algorithm 1 consumes is exactly
what the trainer reports for that job.
"""
import sys

sys.path.insert(0, "src")

from repro.cluster import simulate_shared_cluster  # noqa: E402


def main():
    jobs = ["qwen3-1.7b", "qwen3-1.7b", "olmo-1b"]
    rep = simulate_shared_cluster(jobs, algo="dcqcn", sim_time=4.0)
    print(f"jobs: {rep.jobs}")
    for j, (b, m) in enumerate(zip(rep.baseline_avg, rep.mltcp_avg)):
        print(f"  {rep.jobs[j]:24s} iter {b * 1e3:7.2f} ms -> {m * 1e3:7.2f} ms")
    print(f"avg speedup {rep.avg_speedup:.2f}x  p99 {rep.p99_speedup:.2f}x")
    print(f"comm-phase overlap {rep.interleave_before:.2f} -> "
          f"{rep.interleave_after:.2f} (0 = interleaved)")


if __name__ == "__main__":
    main()
