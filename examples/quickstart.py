"""Quickstart: train a small LM end-to-end on CPU and watch the loss drop.

    PYTHONPATH=src python examples/quickstart.py

Uses the xlstm-125m family (reduced width for CPU), the synthetic Zipf+motif
pipeline, AdamW with cosine schedule, and checkpoint/restore — the same code
path the production launcher uses.
"""
import sys

sys.path.insert(0, "src")

from repro.launch.train import train  # noqa: E402


def main():
    out = train("xlstm-125m", steps=120, seq_len=64, batch=8,
                ckpt_dir="/tmp/repro_quickstart_ckpt", ckpt_every=60)
    print(f"\nloss {out['first_loss']:.3f} -> {out['last_loss']:.3f}")
    assert out["last_loss"] < out["first_loss"], "model failed to learn"
    print("quickstart OK: the model is learning.")


if __name__ == "__main__":
    main()
