"""Batched serving demo: prefill a prompt batch, then greedy-decode with KV
(and recurrent-state) caches.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b
"""
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main  # noqa: E402

if __name__ == "__main__":
    main()
