"""Visualize MLTCP's convergence from real probe data: per-job comm
phases, per-flow cwnd and the interleave detector's overlap signal as
ASCII timelines (the paper's Figures 5 / 7a), before and after enabling
MLTCP — captured by the on-device probe subsystem (`netsim.telemetry`)
instead of the chunk-averaged trace channels.

    PYTHONPATH=src python examples/interleave_demo.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro import netsim, workload  # noqa: E402
from repro.core import Algo, CCParams, MLTCPConfig, Variant  # noqa: E402

DT = 2e-5
SIM_TIME = 3.0

# arm the Fig. 5 probes + both detectors; ~600 samples across the run
SPEC = netsim.TelemetrySpec(
    probes=("flow_cwnd", "job_incomm", "interleave_overlap"),
    stride=int(round(SIM_TIME / DT)) // 600)


def build(pt):
    topo = netsim.dumbbell(2, sockets_per_job=2)
    prof = workload.profile_for("gpt2").scaled(0.25)
    jobs = workload.jobspec_from_profiles([prof, prof])
    variant = Variant.WI if pt["scheme"] == "mltcp" else Variant.OFF
    proto = MLTCPConfig(cc=CCParams(algo=int(Algo.RENO), variant=int(variant),
                                    tick_dt=DT, rtt=100e-6),
                        slope=1.75, intercept=0.25)
    return netsim.SimConfig(topo=topo, jobs=jobs, protocol=proto,
                            sim_time=SIM_TIME, dt=DT, seed=1)


def _cols(series: np.ndarray, width: int = 120) -> np.ndarray:
    """Average a [S, ...] probe series down to `width` display columns."""
    s = series.shape[0] // width * width
    return series[:s].reshape(width, -1, *series.shape[1:]).mean(axis=1)


def shade(u: float) -> str:
    return " .:-=+*#%@"[min(int(u * 9.99), 9)]


def comm_phases(res, title, width=120):
    ic = _cols(res.telemetry.series["job_incomm"], width)
    print(f"\n{title}  (comm-phase probe; each column ~"
          f"{SIM_TIME / width * 1e3:.0f} ms)")
    for j in range(ic.shape[1]):
        print(f"  job{j} |{''.join(shade(u) for u in ic[:, j])}|")
    ov = _cols(res.telemetry.series["interleave_overlap"], width)
    print(f"  ovlp |{''.join(shade(u) for u in ov)}|")


def cwnd_timeline(res, title, width=120):
    cw = _cols(res.telemetry.series["flow_cwnd"], width)
    cw = cw / max(cw.max(), 1e-9)
    print(f"\n{title}  (per-flow cwnd probe, normalized)")
    for n in range(cw.shape[1]):
        print(f"  flow{n}|{''.join(shade(u) for u in cw[:, n])}|")


def main():
    # one declarative plan: the scheme axis is static (the traced program
    # differs), so run_plan compiles two programs and labels both results;
    # telemetry= arms the probe subsystem on every point
    plan = netsim.Plan(name="interleave-demo",
                       axes=(netsim.Axis("scheme", ("default", "mltcp")),),
                       build=build)
    result = netsim.run_plan(plan, telemetry=SPEC)
    (base,), (ml,) = (result.select(scheme="default"),
                      result.select(scheme="mltcp"))
    comm_phases(base, "default Reno — comm phases collide")
    comm_phases(ml, "MLTCP-Reno — comm phases interleave")
    cwnd_timeline(ml, "MLTCP-Reno")

    tti_it = netsim.convergence_iteration(ml)
    print(f"\ntime-to-interleave: MLTCP converges after "
          f"{netsim.time_to_interleave(ml) * 1e3:.0f} ms "
          f"({tti_it:.0f} training iterations); "
          f"default Reno: {'never' if not base.telemetry.converged else 'yes'}")
    print(f"interleave stability (tail): "
          f"{base.telemetry.interleave_stability:.2f} -> "
          f"{ml.telemetry.interleave_stability:.2f} (1 = stays interleaved)")
    print(f"avg iteration: {base.avg_iter(0) * 1e3:.1f} ms -> "
          f"{ml.avg_iter(0) * 1e3:.1f} ms; streaming p99 sketch: "
          f"{netsim.iter_time_quantile(base, 0.99) * 1e3:.1f} ms -> "
          f"{netsim.iter_time_quantile(ml, 0.99) * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
