"""Visualize MLTCP's convergence: per-job link utilization as ASCII art
(the paper's Figure 7a), before and after enabling MLTCP.

    PYTHONPATH=src python examples/interleave_demo.py
"""
import sys

sys.path.insert(0, "src")

from repro import netsim, workload  # noqa: E402
from repro.core import Algo, CCParams, MLTCPConfig, Variant  # noqa: E402

DT = 2e-5


def build(pt):
    topo = netsim.dumbbell(2, sockets_per_job=2)
    prof = workload.profile_for("gpt2").scaled(0.25)
    jobs = workload.jobspec_from_profiles([prof, prof])
    variant = Variant.WI if pt["scheme"] == "mltcp" else Variant.OFF
    proto = MLTCPConfig(cc=CCParams(algo=int(Algo.RENO), variant=int(variant),
                                    tick_dt=DT, rtt=100e-6),
                        slope=1.75, intercept=0.25)
    return netsim.SimConfig(topo=topo, jobs=jobs, protocol=proto,
                            sim_time=3.0, dt=DT, seed=1, n_chunks=600)


def ascii_trace(res, title, tail=120):
    tput = res.trace_jobtput[-tail:] / 6.25e9
    print(f"\n{title}  (each column = one trace chunk; rows = jobs)")
    for j in range(tput.shape[1]):
        line = "".join(" .:-=+*#%@"[min(int(u * 9.99), 9)] for u in tput[:, j])
        print(f"  job{j} |{line}|")


def main():
    # one declarative plan: the scheme axis is static (the traced program
    # differs), so run_plan compiles two programs and labels both results
    plan = netsim.Plan(name="interleave-demo",
                       axes=(netsim.Axis("scheme", ("default", "mltcp")),),
                       build=build)
    result = netsim.run_plan(plan)
    (base,), (ml,) = (result.select(scheme="default"),
                      result.select(scheme="mltcp"))
    ascii_trace(base, "default Reno — comm phases collide")
    ascii_trace(ml, "MLTCP-Reno — comm phases interleave")
    print(f"\ninterleave score: {netsim.mean_pairwise_interleave(base):.2f} "
          f"-> {netsim.mean_pairwise_interleave(ml):.2f} (0 = interleaved)")
    print(f"avg iteration: {base.avg_iter(0) * 1e3:.1f} ms -> "
          f"{ml.avg_iter(0) * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
