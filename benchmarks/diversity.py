"""Figure 11 / Table 2 — DNN model & parallelization-strategy diversity.

The Table-2 snapshots (different models, batch sizes, parallelism, placement
on the two-tier fabric) run with DCQCN vs MLQCN; "ideal" is each job in
isolation. The paper: MLQCN lands within ~5% of ideal on average.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import netsim, workload


def run() -> tuple[dict, int]:
    out = {}
    n_sims = 0
    for snap in workload.table2_snapshots(sockets_per_job=2):
        profs = list(snap.profiles)
        base = common.sim(snap.topo, profs, common.protocol("dcqcn", "OFF"))
        ml = common.sim(snap.topo, profs, common.protocol("dcqcn", "WI"))
        # isolation: each job alone on the fabric
        iso_avgs = []
        for j, p in enumerate(profs):
            solo = common.sim(snap.topo, [p], common.protocol("dcqcn", "OFF"))
            iso_avgs.append(solo.avg_iter(0))
        sp = netsim.speedup_stats(base, ml)
        ml_avgs = [ml.avg_iter(j) for j in range(len(profs))]
        out[snap.name] = {
            "compat_measured": round(workload.compatibility_score(
                profs[0].scaled(common.WORK_SCALE),
                profs[1].scaled(common.WORK_SCALE)), 3),
            "compat_paper": snap.compat_paper,
            "avg_speedup": round(sp["avg_speedup"], 3),
            "p99_speedup": round(sp["p99_speedup"], 3),
            "vs_ideal": round(float(np.mean(
                [m / i for m, i in zip(ml_avgs, iso_avgs)])), 3),
        }
        n_sims += 2 + len(profs)
    return out, int(common.SIM_TIME / common.DT) * n_sims


if __name__ == "__main__":
    import json
    print(json.dumps(run()[0], indent=1))
