"""Figure 11 / Table 2 — DNN model & parallelization-strategy diversity.

The Table-2 snapshots (different models, batch sizes, parallelism, placement
on the two-tier fabric) run with DCQCN vs MLQCN; "ideal" is each job in
isolation. The paper: MLQCN lands within ~5% of ideal on average.

One plan over all snapshots: snap x scheme x solo x seed.  Snapshots share
the two-tier fabric and differ only in their phase programs, which are
traced workload leaves — so snapshots with the same phase *structure* merge
into one compile group per scheme (snapshots whose P_max differs, e.g. the
hybrid-parallel GPT-3 program, get their own).  Isolation is expressed with
the padded-jobs mask (`job_active` one-hot per job), so every "job alone on
the fabric" run keeps the full topology/JobSpec — faithful isolation on the
same links — and shares the baseline scheme's compile group instead of
compiling per job.  All reported numbers are seed-averaged.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import netsim, workload


def run() -> tuple[dict, int]:
    snaps = workload.table2_snapshots(sockets_per_job=2)
    by_name = {s.name: s for s in snaps}
    n = 2                       # every Table-2 snapshot pairs two jobs

    def solo_mask(v):
        if v == "all":
            return np.ones((n,), bool)
        mask = np.zeros((n,), bool)
        mask[v] = True
        return mask

    def build(pt):
        snap = by_name[pt["snap"]]
        variant = "WI" if pt["scheme"] == "mlqcn" else "OFF"
        return common.build_cfg(snap.topo, list(snap.profiles),
                                common.protocol("dcqcn", variant))

    pr = common.run_plan(common.plan(
        build, name="table2",
        # isolation points only need the baseline protocol
        where=lambda pt: pt["solo"] == "all" or pt["scheme"] == "base",
        snap=tuple(by_name),
        scheme=("base", "mlqcn"),
        solo=netsim.Axis("solo", ("all",) + tuple(range(n)),
                         field="job_active", resolve=solo_mask),
        seed=common.seed_axis()))
    # one group per (scheme, phase-structure): single-phase snapshots merge
    assert pr.n_compile_groups <= 4, pr.n_compile_groups
    assert pr.n_kernel_fallbacks == 0

    out = {}
    for snap in snaps:
        profs = list(snap.profiles)
        base = pr.select(snap=snap.name, scheme="base", solo="all")
        ml = pr.select(snap=snap.name, scheme="mlqcn", solo="all")
        sp = netsim.sweep_speedup_stats(base, ml)
        # per-job: MLQCN's seed-mean avg iter vs the job's isolation run
        # (warmup=2: short smoke windows record few iterations per job)
        vs_ideal = []
        for j in range(len(profs)):
            iso = np.mean([r.avg_iter(j, warmup=2)
                           for r in pr.select(snap=snap.name, scheme="base",
                                              solo=j)])
            mlj = np.mean([r.avg_iter(j, warmup=2) for r in ml])
            vs_ideal.append(mlj / iso)
        out[snap.name] = {
            "compat_measured": round(workload.compatibility_score(
                profs[0].scaled(common.WORK_SCALE),
                profs[1].scaled(common.WORK_SCALE)), 3),
            "compat_paper": snap.compat_paper,
            "avg_speedup": round(sp["avg_speedup"], 3),
            "p99_speedup": round(sp["p99_speedup"], 3),
            "vs_ideal": round(float(np.mean(vs_ideal)), 3),
        }
    return out, pr.n_ticks


if __name__ == "__main__":
    import json
    print(json.dumps(run()[0], indent=1))
