"""Figure 11 / Table 2 — DNN model & parallelization-strategy diversity.

The Table-2 snapshots (different models, batch sizes, parallelism, placement
on the two-tier fabric) run with DCQCN vs MLQCN; "ideal" is each job in
isolation. The paper: MLQCN lands within ~5% of ideal on average.

One plan per snapshot: scheme x solo x seed.  Isolation is expressed with
the padded-jobs mask (`job_active` one-hot per job), so every "job alone on
the fabric" run keeps the full topology/JobSpec — faithful isolation on the
same links — and shares the baseline scheme's compile group instead of
compiling per job.  All reported numbers are seed-averaged.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import netsim, workload


def _snapshot_plan(snap) -> netsim.Plan:
    profs = list(snap.profiles)
    n = len(profs)

    def solo_mask(v):
        if v == "all":
            return np.ones((n,), bool)
        mask = np.zeros((n,), bool)
        mask[v] = True
        return mask

    def build(pt):
        variant = "WI" if pt["scheme"] == "mlqcn" else "OFF"
        return common.build_cfg(snap.topo, profs,
                                common.protocol("dcqcn", variant))

    return common.plan(
        build, name=f"table2-{snap.name}",
        # isolation points only need the baseline protocol
        where=lambda pt: pt["solo"] == "all" or pt["scheme"] == "base",
        scheme=("base", "mlqcn"),
        solo=netsim.Axis("solo", ("all",) + tuple(range(n)),
                         field="job_active", resolve=solo_mask),
        seed=common.seed_axis())


def run() -> tuple[dict, int]:
    out = {}
    n_ticks = 0
    for snap in workload.table2_snapshots(sockets_per_job=2):
        profs = list(snap.profiles)
        pr = common.run_plan(_snapshot_plan(snap))
        assert pr.n_compile_groups == 2, pr.n_compile_groups
        base = pr.select(scheme="base", solo="all")
        ml = pr.select(scheme="mlqcn", solo="all")
        sp = netsim.sweep_speedup_stats(base, ml)
        # per-job: MLQCN's seed-mean avg iter vs the job's isolation run
        # (warmup=2: short smoke windows record few iterations per job)
        vs_ideal = []
        for j in range(len(profs)):
            iso = np.mean([r.avg_iter(j, warmup=2)
                           for r in pr.select(scheme="base", solo=j)])
            mlj = np.mean([r.avg_iter(j, warmup=2) for r in ml])
            vs_ideal.append(mlj / iso)
        out[snap.name] = {
            "compat_measured": round(workload.compatibility_score(
                profs[0].scaled(common.WORK_SCALE),
                profs[1].scaled(common.WORK_SCALE)), 3),
            "compat_paper": snap.compat_paper,
            "avg_speedup": round(sp["avg_speedup"], 3),
            "p99_speedup": round(sp["p99_speedup"], 3),
            "vs_ideal": round(float(np.mean(vs_ideal)), 3),
        }
        n_ticks += pr.n_ticks
    return out, n_ticks


if __name__ == "__main__":
    import json
    print(json.dumps(run()[0], indent=1))
