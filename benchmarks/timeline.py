"""Figures 5 / 7a — interleaving timelines and time-to-interleave.

The paper's headline *dynamic* claim: MLTCP flows "stabilize into an
interleaved state within a few training iterations" (Fig. 5 shows the
per-flow cwnd timelines pulling apart; Fig. 7a the link view).  The
chunk-averaged ``trace_*`` channels are too coarse for that, so this suite
arms the probe subsystem (`netsim.telemetry`): decimated per-flow cwnd /
rate and per-link queue series captured inside the scan, plus the
streaming interleave detector whose time-to-interleave is the claim as a
number — measured for MLTCP-Reno, MLTCP-CUBIC and MLQCN (the DCQCN
variant) against their unmodified baselines on a 2-job contended dumbbell.

The suite asserts the paper's shape: every MLTCP variant converges within
``MAX_TTI_ITERS`` training iterations, the baselines never do.  Raw
timeline arrays land in ``results/timelines/<algo>.npz`` for plotting, and
the run doubles as the `PlanResult.profile` exercise (per-group trace /
compile / execute split + device footprint).
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks import common
from repro import netsim

# the paper's "within a few training iterations" bound we hold MLTCP to
MAX_TTI_ITERS = 10.0

TIMELINES_DIR = os.path.join("results", "timelines")


def telemetry_spec() -> netsim.TelemetrySpec:
    """The suite's probe arming: Fig. 5/7a series + both detectors.

    The stride targets ~1000 samples per run at any SIM_TIME; capture
    stays O(samples) on device, so the suite's footprint is flat whether
    smoke (1.5 s) or full (20 s) scale.
    """
    n_ticks = int(round(common.SIM_TIME / common.DT))
    stride = max(1, n_ticks // 1000)
    return netsim.TelemetrySpec(
        probes=("flow_cwnd", "flow_rate", "link_queue", "link_mark_rate",
                "job_incomm", "job_iter", "interleave_overlap"),
        stride=stride)


def _mean_finite(xs: list[float]) -> float:
    xs = [x for x in xs if np.isfinite(x)]
    return float(np.mean(xs)) if xs else float("inf")


def _jsonable(x: float):
    return x if np.isfinite(x) else None      # inf: keep the JSON strict


def _save_timeline(algo: str, res: netsim.SimResult) -> str:
    tl = res.telemetry
    os.makedirs(TIMELINES_DIR, exist_ok=True)
    path = os.path.join(TIMELINES_DIR, f"{algo}.npz")
    np.savez_compressed(
        path, t=tl.t,
        flow_cwnd=tl.series["flow_cwnd"],
        flow_rate=tl.series["flow_rate"],
        link_queue=tl.series["link_queue"],
        job_incomm=tl.series["job_incomm"],
        overlap=tl.series["interleave_overlap"],
        time_to_interleave_s=tl.time_to_interleave_s,
        time_to_interleave_iters=tl.time_to_interleave_iters)
    return path


def _summarize(algo: str, base: list[netsim.SimResult],
               ml: list[netsim.SimResult]) -> dict:
    tti_ml = [netsim.convergence_iteration(r) for r in ml]
    tti_base = [netsim.convergence_iteration(r) for r in base]
    peak_q = float(np.max([r.telemetry.series["link_queue"].max()
                           for r in ml]))
    out = {
        "algo": algo,
        "tti_iters": _jsonable(_mean_finite(tti_ml)),
        "tti_seconds": _jsonable(_mean_finite(
            [netsim.time_to_interleave(r) for r in ml])),
        "baseline_tti_iters": _jsonable(_mean_finite(tti_base)),
        "converged_frac": float(np.mean(
            [r.telemetry.converged for r in ml])),
        "baseline_converged_frac": float(np.mean(
            [r.telemetry.converged for r in base])),
        "interleave_stability": float(np.mean(
            [r.telemetry.interleave_stability for r in ml])),
        "p50_iter_s": netsim.iter_time_quantile(ml[0], 0.50),
        "p99_iter_s": netsim.iter_time_quantile(ml[0], 0.99),
        "baseline_p99_iter_s": netsim.iter_time_quantile(base[0], 0.99),
        "peak_queue_bytes": peak_q,
        "timeline_npz": _save_timeline(algo, ml[0]),
    }
    # the paper's claim, enforced: MLTCP interleaves within a few
    # iterations; the unmodified baseline stays synchronized
    assert all(np.isfinite(x) and x <= MAX_TTI_ITERS for x in tti_ml), \
        f"{algo}: MLTCP time-to-interleave {tti_ml} exceeds {MAX_TTI_ITERS}"
    assert not any(r.telemetry.converged for r in base), \
        f"{algo}: unmodified baseline unexpectedly interleaved {tti_base}"
    return out


# paper §4.1: TCP jobs open parallel sockets, RoCE uses a single QP — and
# MLQCN's rate-based adjustment needs the single-QP setup to interleave
# within a few iterations (multi-QP splits the per-flow signal)
SOCKETS = {"reno": 2, "cubic": 2, "dcqcn": 1}


def make_plan(algos=("reno", "cubic", "dcqcn"), sockets=None) -> netsim.Plan:
    """The fig5 grid as a plan (lintable via `repro.analysis --plan fig5`;
    the analyzer stamps `telemetry_spec()` on to lint the armed lowering)."""
    profs = common.gpt2(2)

    def build(pt):
        n_sock = SOCKETS[pt["algo"]] if sockets is None else sockets
        topo = netsim.dumbbell(2, sockets_per_job=n_sock)
        return common.build_cfg(topo, profs,
                                common.protocol(pt["algo"], pt["variant"]))

    return common.plan(
        build, name="fig5-timeline",
        algo=tuple(algos), variant=("OFF", "WI"), seed=common.seed_axis())


def run(algos=("reno", "cubic", "dcqcn"), sockets=None) -> tuple[dict, int]:
    pr = common.run_plan(make_plan(algos, sockets),
                         telemetry=telemetry_spec(), profile=True)
    out = {algo: _summarize(algo,
                            pr.select(algo=algo, variant="OFF"),
                            pr.select(algo=algo, variant="WI"))
           for algo in algos}
    out["_profile"] = pr.profile.summary()
    return out, pr.n_ticks


if __name__ == "__main__":
    import json
    res, _ = run()
    print(json.dumps(res, indent=1))
