"""Figures 7, 8, 9 — convergence benchmarks.

Two GPT-2 data-parallel jobs share the dumbbell; compare default Reno /
CUBIC / DCQCN against their MLTCP variants on: interleave convergence
(iterations until the comm phases separate), drop/ECN-mark rate, and avg /
p99 training-iteration times.  One plan: algo x variant x seed — each
(algo, variant) scheme is its own compile group (the program differs), and
the seed axis batches the error-bar runs inside each group.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import netsim


def _converged_iteration(res: netsim.SimResult) -> float:
    """First iteration index after which per-iteration times stay within 10%
    of the tail median (the paper's 'stabilizes after ~N iterations')."""
    xs = res.iter_times[0]
    if xs.size < 10:
        return float("nan")
    tail = np.median(xs[len(xs) // 2:])
    ok = np.abs(xs - tail) <= 0.1 * tail
    for i in range(len(ok)):
        if ok[i:].all():
            return float(i)
    return float(len(ok))


def _ratio(nums, dens) -> float:
    nums, dens = float(np.mean(nums)), float(np.mean(dens))
    return nums / dens if dens > 0 else float("inf")


def _summarize(algo: str, base: list[netsim.SimResult],
               ml: list[netsim.SimResult]) -> dict:
    sp = netsim.sweep_speedup_stats(base, ml)
    return {
        "algo": algo,
        "baseline_interleave": float(np.mean(
            [netsim.mean_pairwise_interleave(r) for r in base])),
        "mltcp_interleave": float(np.mean(
            [netsim.mean_pairwise_interleave(r) for r in ml])),
        "converged_at_iter": float(np.nanmean(
            [_converged_iteration(r) for r in ml])),
        "drop_reduction": _ratio([r.drops_per_s for r in base],
                                 [r.drops_per_s for r in ml]),
        "mark_reduction": _ratio([r.marks_per_s for r in base],
                                 [r.marks_per_s for r in ml]),
        "avg_speedup": sp["avg_speedup"],
        "avg_speedup_std": sp["avg_speedup_std"],
        "p99_speedup": sp["p99_speedup"],
    }


def run(algos=("reno", "cubic", "dcqcn"), sockets: int = 2) -> tuple[dict, int]:
    topo = netsim.dumbbell(2, sockets_per_job=sockets)
    profs = common.gpt2(2)
    pr = common.run_plan(common.plan(
        lambda pt: common.build_cfg(topo, profs,
                                    common.protocol(pt["algo"], pt["variant"])),
        name="fig7-9",
        algo=tuple(algos), variant=("OFF", "WI"), seed=common.seed_axis()))
    out = {algo: _summarize(algo,
                            pr.select(algo=algo, variant="OFF"),
                            pr.select(algo=algo, variant="WI"))
           for algo in algos}
    return out, pr.n_ticks


if __name__ == "__main__":
    import json
    res, _ = run()
    print(json.dumps(res, indent=1))
