"""Figures 7, 8, 9 — convergence benchmarks.

Two GPT-2 data-parallel jobs share the dumbbell; compare default Reno /
CUBIC / DCQCN against their MLTCP variants on: interleave convergence
(iterations until the comm phases separate), drop/ECN-mark rate, and avg /
p99 training-iteration times.  Every scheme runs its multi-seed grid as one
batched `simulate_sweep`, so the reported metrics are seed-averaged with
error bars for free.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import netsim


def _converged_iteration(res: netsim.SimResult) -> float:
    """First iteration index after which per-iteration times stay within 10%
    of the tail median (the paper's 'stabilizes after ~N iterations')."""
    xs = res.iter_times[0]
    if xs.size < 10:
        return float("nan")
    tail = np.median(xs[len(xs) // 2:])
    ok = np.abs(xs - tail) <= 0.1 * tail
    for i in range(len(ok)):
        if ok[i:].all():
            return float(i)
    return float(len(ok))


def _ratio(nums, dens) -> float:
    nums, dens = float(np.mean(nums)), float(np.mean(dens))
    return nums / dens if dens > 0 else float("inf")


def run_one(algo: str, sockets: int = 2) -> dict:
    topo = netsim.dumbbell(2, sockets_per_job=sockets)
    profs = common.gpt2(2)
    base = common.sim_seeds(topo, profs, common.protocol(algo, "OFF"))
    ml = common.sim_seeds(topo, profs, common.protocol(algo, "WI"))
    sp = netsim.sweep_speedup_stats(base, ml)
    return {
        "algo": algo,
        "baseline_interleave": float(np.mean(
            [netsim.mean_pairwise_interleave(r) for r in base])),
        "mltcp_interleave": float(np.mean(
            [netsim.mean_pairwise_interleave(r) for r in ml])),
        "converged_at_iter": float(np.nanmean(
            [_converged_iteration(r) for r in ml])),
        "drop_reduction": _ratio([r.drops_per_s for r in base],
                                 [r.drops_per_s for r in ml]),
        "mark_reduction": _ratio([r.marks_per_s for r in base],
                                 [r.marks_per_s for r in ml]),
        "avg_speedup": sp["avg_speedup"],
        "avg_speedup_std": sp["avg_speedup_std"],
        "p99_speedup": sp["p99_speedup"],
    }


def run(algos=("reno", "cubic", "dcqcn")) -> tuple[dict, int]:
    out = {}
    for algo in algos:
        out[algo] = run_one(algo)
    n_ticks = int(common.SIM_TIME / common.DT) * 2 * len(algos) \
        * len(common.SEEDS)
    return out, n_ticks


if __name__ == "__main__":
    import json
    res, _ = run()
    print(json.dumps(res, indent=1))
