"""Figure 12 — robustness to stragglers.

Two GPT-2 jobs with per-iteration straggle probability p (sleep 5-10% of the
isolation time). Compare MLQCN and Cassini (both normalized to default
DCQCN). The paper: MLQCN's speedup is flat in p; Cassini's tail collapses
beyond p ~ 10% because its agent forces re-alignment skips.

One plan: p x scheme x seed.  The straggle probability is a *dynamic* sweep
axis (a traced `straggle_prob` leaf), and the Cassini schedule rides the
traced cassini leaves, so the whole grid folds into two compile groups —
{base, cassini} x all p (variant OFF) and mlqcn x all p (variant WI) — with
the multi-seed error bars batched on the same sweep axis.
"""
from __future__ import annotations

from benchmarks import common
from repro import netsim, workload


def make_plan(probs=(0.0, 0.05, 0.10, 0.20, 0.30)) -> netsim.Plan:
    """The fig12 grid as a plan, buildable without running (the static
    analyzer lints exactly this object via `repro.analysis --plan fig12`)."""
    topo = netsim.dumbbell(2, sockets_per_job=2)
    profs = common.gpt2(2)
    sched, _ = workload.cassini_schedule(
        topo, [pr.scaled(common.WORK_SCALE) for pr in profs])

    def build(pt):
        variant = "WI" if pt["scheme"] == "mlqcn" else "OFF"
        return common.build_cfg(
            topo, profs, common.protocol("dcqcn", variant),
            cassini=sched if pt["scheme"] == "cassini" else None)

    return common.plan(
        build, name="fig12",
        p=netsim.Axis("p", tuple(probs), field="straggle_prob"),
        scheme=("base", "mlqcn", "cassini"),
        seed=common.seed_axis())


def run(probs=(0.0, 0.05, 0.10, 0.20, 0.30)) -> tuple[dict, int]:
    pr = common.run_plan(make_plan(probs))
    assert pr.n_compile_groups <= 2, pr.n_compile_groups
    assert pr.n_kernel_fallbacks == 0
    out = {}
    for p in probs:
        base = pr.select(p=p, scheme="base")
        sp_ml = netsim.sweep_speedup_stats(base, pr.select(p=p, scheme="mlqcn"))
        sp_cas = netsim.sweep_speedup_stats(base,
                                            pr.select(p=p, scheme="cassini"))
        out[f"p={p}"] = {
            "mlqcn_avg": round(sp_ml["avg_speedup"], 3),
            "mlqcn_p99": round(sp_ml["p99_speedup"], 3),
            "cassini_avg": round(sp_cas["avg_speedup"], 3),
            "cassini_p99": round(sp_cas["p99_speedup"], 3),
        }
    return out, pr.n_ticks


if __name__ == "__main__":
    import json
    print(json.dumps(run()[0], indent=1))
