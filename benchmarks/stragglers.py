"""Figure 12 — robustness to stragglers.

Two GPT-2 jobs with per-iteration straggle probability p (sleep 5-10% of the
isolation time). Compare MLQCN and Cassini (both normalized to default
DCQCN). The paper: MLQCN's speedup is flat in p; Cassini's tail collapses
beyond p ~ 10% because its agent forces re-alignment skips.
"""
from __future__ import annotations

from benchmarks import common
from repro import netsim, workload


def run(probs=(0.0, 0.05, 0.10, 0.20, 0.30)) -> tuple[dict, int]:
    topo = netsim.dumbbell(2, sockets_per_job=2)
    profs = common.gpt2(2)
    out = {}
    n_sims = 0
    for p in probs:
        sp_vec = [p, p]
        base = common.sim(topo, profs, common.protocol("dcqcn", "OFF"),
                          straggle_prob=sp_vec)
        ml = common.sim(topo, profs, common.protocol("dcqcn", "WI"),
                        straggle_prob=sp_vec)
        sched, _ = workload.cassini_schedule(
            topo, [pr.scaled(common.WORK_SCALE) for pr in profs])
        cas = common.sim(topo, profs, common.protocol("dcqcn", "OFF"),
                         straggle_prob=sp_vec, cassini=sched)
        sp_ml = netsim.speedup_stats(base, ml)
        sp_cas = netsim.speedup_stats(base, cas)
        out[f"p={p}"] = {
            "mlqcn_avg": round(sp_ml["avg_speedup"], 3),
            "mlqcn_p99": round(sp_ml["p99_speedup"], 3),
            "cassini_avg": round(sp_cas["avg_speedup"], 3),
            "cassini_p99": round(sp_cas["p99_speedup"], 3),
        }
        n_sims += 3
    return out, int(common.SIM_TIME / common.DT) * n_sims


if __name__ == "__main__":
    import json
    print(json.dumps(run()[0], indent=1))
