"""Fault-injection gauntlet — re-convergence under churn, flaps, blackholes.

The paper's robustness claim (§1, §5.4) is that MLTCP interleaves
"regardless of the number of competing flows or the start time of each
flow" — a statement about *re*-convergence, not just cold starts.  This
suite runs a 3-job contended dumbbell through a scripted fault gauntlet
(arrival -> first-hop blackhole -> departure -> re-arrival -> bottleneck
flap) and measures, per fault-event window, how many training iterations
MLTCP needs to re-interleave (`netsim.telemetry`'s "reinterleave"
detector, DESIGN.md §8) — for MLTCP-Reno / MLTCP-CUBIC / MLQCN against
their unmodified baselines, on the fused Pallas CC-tick kernel path.

The suite asserts the robustness shape: after every fault boundary MLTCP
re-stabilizes within ``MAX_REINTERLEAVE_ITERS`` training iterations (the
window while a socket blackhole is *actively* null-routing is reported
but exempt — interleaving is ill-defined while flows are unplugged) and
holds interleave stability >=0.95 across the whole gauntlet; the
baselines never shake off their synchronized episodes (at least one
fault window never re-converges, and baseline stability sits strictly
below MLTCP's on the identical gauntlet).
Fault *schedules* are `SweepParams`
leaves via an ``Axis(field="*")``, so the whole schedule grid batches
into one compile group per (algo, variant) — arming faults costs zero
extra traces beyond the armed program itself.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import netsim

# the paper's "within a few training iterations" bound, held per fault event
MAX_REINTERLEAVE_ITERS = 10.0

# §4.1 socket counts (multi-socket TCP, single-QP RoCE) — as in timeline.py
SOCKETS = {"reno": 2, "cubic": 2, "dcqcn": 1}

N_JOBS = 3

# The gauntlet runs on the *iteration* clock, not the suite's wall-clock
# budget: event times are fractions of the run, and the per-event bound
# ("re-interleaves within 10 training iterations") only means the same
# thing at every scale if each fault window spans the same number of
# iterations.  Iteration duration scales with common.WORK_SCALE, so the
# run does too — 4.5 s at the 0.25x smoke/quick workload (each window
# spans ~4-8 iterations, comfortably above the 10-iter assertion's
# resolution and validated empirically), 18 s at full.  Tying it to
# common.SIM_TIME instead stretches every window ~3x in iterations at
# quick scale, and the interleaved band's rare brush-ups past the
# overlap threshold then land *late inside* the longer windows,
# inflating the measured re-interleave time without any change in the
# underlying dynamics.
SIM_TIME = 18.0 * common.WORK_SCALE

# Double the dumbbell bottleneck: the 3-job episode must be *feasible*
# (three gpt2 comm phases cannot slot into the 2-job 50 Gbps capacity;
# at 100 Gbps the sum duty is ~0.75 and MLTCP interleaves all three) and
# every post-event window needs slack to re-lock in bounded iterations —
# at exact saturation a perturbed two-body state re-sorts only on its own
# slow beat timescale.  The degraded-optic flap (0.5x -> 50 Gbps) is the
# saturated-contention window where the baselines' synchronization is
# starkest.
CAP_GBPS = 100.0

# Event-table structure shared by every schedule label: 8 boundary rows
# (t=0 baseline, arrival, blackhole open/close, departure, re-arrival,
# flap open/close).  One spec => one compile group per (algo, variant)
# across all schedules.
SPEC = netsim.FaultSpec(n_events=8, churn=True, link_flaps=True,
                        blackholes=True)

SCHEDULES = ("gauntlet", "staggered")


def _job_flows(cfg: netsim.SimConfig, job: int) -> list[int]:
    return [int(f) for f in
            np.nonzero(np.asarray(cfg.topo.flow_to_job) == job)[0]]


def _events(cfg: netsim.SimConfig, label: str) -> list:
    """The labeled gauntlet on ``cfg``'s fabric, timed as fractions of the
    run so smoke and full scale exercise the same shape.

    The churned job is *absent at t=0* (a departure folded into row 0), so
    the steady fabric is 2 resident jobs, and the gauntlet runs a full
    churn cycle: the third job arrives, one resident socket is
    blackholed, the churned job departs mid-run, *re-arrives*, and a
    degraded-optic capacity flap (0.88-0.9x) hits the second 3-job
    episode.  Two structural rules, learned the hard way: (1) every
    perturbation lands while the fabric is *contended* (sum duty ~0.75+)
    — re-convergence needs a congestion gradient to sort against, and a
    scramble injected into a slack fabric leaves the flows phase-locked
    with no restoring force; (2) every asserted window is *bounded* by
    the next event (the run ends inside the contended 3-job regime, the
    only one whose restoring force also corrects slow phase drift over a
    long unbounded tail)."""
    T = cfg.sim_time
    if label == "gauntlet":
        churn_job, bh_job = 2, 0
        arr, dep, rearr = 0.08 * T, 0.30 * T, 0.38 * T
        bh = (0.18 * T, 0.22 * T)
        flap = (0.50 * T, 0.64 * T, 0.88)
    elif label == "staggered":
        churn_job, bh_job = 1, 2
        arr, dep, rearr = 0.10 * T, 0.32 * T, 0.40 * T
        bh = (0.20 * T, 0.24 * T)
        flap = (0.52 * T, 0.66 * T, 0.9)
    else:
        raise ValueError(f"unknown schedule label {label!r}")
    return [
        netsim.job_departs(0.0, churn_job),
        netsim.job_arrives(arr, churn_job),
        netsim.job_departs(dep, churn_job),
        netsim.job_arrives(rearr, churn_job),
        netsim.link_flap(flap[0], flap[1], 0, flap[2]),
        # null-route ONE socket of a resident job while the 3-job episode
        # is live: the loss-signal + retransmit path under test, with the
        # headroom to re-lock (a whole-job hole at a *saturated* link
        # leaves a metastable two-body state that re-sorts only on its own
        # slow beat timescale)
        netsim.blackhole(bh[0], bh[1], _job_flows(cfg, bh_job)[:1]),
    ]


def _window_names(cfg: netsim.SimConfig, label: str) -> dict[int, str]:
    """start tick -> semantic window name, for per-event-type asserts."""
    _, arr, dep, rearr, flap, bh = _events(cfg, label)
    to_tick = lambda t: max(0, int(round(t / cfg.dt)))
    return {
        0: "cold-start",
        to_tick(arr.t): "arrival",
        to_tick(dep.t): "departure",
        to_tick(rearr.t): "re-arrival",
        to_tick(flap.t): "flap",
        to_tick(flap.t_end): "flap-clear",
        to_tick(bh.t): "blackhole-active",
        to_tick(bh.t_end): "blackhole-clear",
    }


def make_schedule(cfg: netsim.SimConfig, label: str) -> netsim.FaultSchedule:
    return netsim.fault_schedule(cfg, _events(cfg, label), spec=SPEC)


def telemetry_spec() -> netsim.TelemetrySpec:
    """Arm the overlap machinery plus the per-event re-interleave detector
    (opt-in; needs ``cfg.faults``).  Same ~1000-sample decimation policy as
    the timeline suite."""
    n_ticks = int(round(SIM_TIME / common.DT))
    return netsim.TelemetrySpec(
        probes=("interleave_overlap", "job_iter"),
        detectors=("interleave", "iter_sketch", "reinterleave"),
        # the 3-way interleaved band oscillates at 0.2-0.55 pairwise
        # overlap with transient brush-ups to ~0.75; synchronized
        # baselines sit near 1.0 persistently — 0.8 sits *between* the
        # two regimes, so a brush-up isn't scored as lost convergence
        # while a synchronized baseline still never clears
        overlap_threshold=0.8,
        stride=max(1, n_ticks // 1000))


def make_plan(algos=("reno", "cubic", "dcqcn")) -> netsim.Plan:
    """algo x {OFF, WI} x schedule x seed.  The schedule axis targets
    ``field="*"``: each label resolves (per point config — blackhole tables
    are [E, n_flows] and n_flows tracks the socket count) to the full
    `FaultSchedule.overrides()` dict, so schedules ride the batched sweep
    and the grid stays at one compile group per (algo, variant)."""
    profs = common.gpt2(N_JOBS)

    def build(pt):
        topo = netsim.dumbbell(N_JOBS, sockets_per_job=SOCKETS[pt["algo"]],
                               cap_gbps=CAP_GBPS)
        return common.build_cfg(
            topo, profs, common.protocol(pt["algo"], pt["variant"]),
            sim_time=SIM_TIME,
            faults=SPEC, telemetry=telemetry_spec(),
            use_pallas_kernel=True)

    return common.plan(
        build, name="churn-gauntlet",
        algo=tuple(algos), variant=("OFF", "WI"),
        schedule=netsim.Axis(
            "schedule", SCHEDULES, field="*",
            resolve=lambda label: (
                lambda cfg: make_schedule(cfg, label).overrides())),
        seed=common.seed_axis())


def _event_rows(res: netsim.SimResult, label: str) -> list[dict]:
    """Per-event report rows with semantic names (pad rows in the event
    table duplicate the last boundary, so names match on start tick)."""
    names = _window_names(res.cfg, label)
    rows = []
    for rep in res.telemetry.fault_events:
        rows.append({
            "window": names.get(rep.start_tick,
                                f"tick{rep.start_tick}"),
            "start_t": rep.start_t,
            "disrupted": rep.disrupted,
            "reconverged": rep.reconverged,
            "reinterleave_iters": (
                rep.reinterleave_iters
                if np.isfinite(rep.reinterleave_iters) else None),
        })
    return rows


# windows exempt from the MLTCP re-convergence bound: while flows are
# null-routed their job cannot take part in bandwidth interleaving (the
# claim we hold is that MLTCP re-interleaves once the hole *closes*), and
# the row-0 window is the t=0 baseline, not a fault — cold-start
# convergence is the convergence suite's claim (and for DCQCN the slack
# 2-job cold fabric offers no congestion signal to sort against at all)
_EXEMPT = ("blackhole-active", "cold-start")

# every fault type must appear among the asserted (non-exempt) windows
_REQUIRED = ("departure", "arrival", "re-arrival", "flap", "flap-clear",
             "blackhole-clear")

# The baseline contrast is *distributional*, not per-window: at partial
# contention (sum duty ~0.75) an unmodified baseline is not pinned in
# sync — it oscillates into and out of synchronized episodes for the
# whole run (reno-OFF measured here: stability 0.53-0.66, >=27% of
# post-cold samples above threshold), so any single window can
# transiently read as "re-converged".  What never happens is the
# episodes dying out: across the gauntlet every baseline run has fault
# windows it never cleanly re-converges from (the primary contrast,
# asserted for every algo), and the TCP baselines' interleave stability
# additionally sits strictly below their MLTCP counterparts on the
# identical gauntlet.  DCQCN is exempt from the *stability* margin
# only: its RED/ECN marks slowly de-phase single-QP flows regardless of
# MLTCP, so on long tails dcqcn-OFF can drift into a fully de-phased
# state (stability up to 1.0, seed-dependent) — for RoCE the claim is
# the *speed* of re-interleaving after each fault, which the per-event
# contrast above already pins, not the asymptotic tail state.
_ML_MIN_STABILITY = 0.95
_BASE_STABILITY_MARGIN = {"reno": 0.02, "cubic": 0.02, "dcqcn": 0.0}


def _summarize(algo: str, label: str, base: list[netsim.SimResult],
               ml: list[netsim.SimResult]) -> dict:
    ml_rows = [_event_rows(r, label) for r in ml]
    base_rows = [_event_rows(r, label) for r in base]
    worst: dict[str, float] = {}
    for rows in ml_rows:
        for row in rows:
            it = (row["reinterleave_iters"]
                  if row["reinterleave_iters"] is not None else float("inf"))
            worst[row["window"]] = max(worst.get(row["window"], 0.0), it)
    out = {
        "algo": algo, "schedule": label,
        "worst_reinterleave_iters": {
            k: (v if np.isfinite(v) else None) for k, v in worst.items()},
        "events": ml_rows[0],
        "baseline_events": base_rows[0],
        "ml_stability": float(min(
            r.telemetry.interleave_stability for r in ml)),
        "baseline_stability": float(max(
            r.telemetry.interleave_stability for r in base)),
        "baseline_reconverged_frac": float(np.mean(
            [row["reconverged"] for rows in base_rows for row in rows])),
    }
    # the robustness claim, enforced per fault event: MLTCP re-interleaves
    # within a few training iterations after every boundary (worst case
    # over seeds) and stays interleaved between them
    held = {k: v for k, v in worst.items() if k not in _EXEMPT}
    missing = [w for w in _REQUIRED if w not in held]
    assert not missing, \
        f"{algo}/{label}: fault windows never observed: {missing}"
    bad = {k: v for k, v in held.items() if v > MAX_REINTERLEAVE_ITERS}
    assert not bad, (f"{algo}/{label}: MLTCP re-interleave exceeded "
                    f"{MAX_REINTERLEAVE_ITERS} iters: {bad}")
    assert out["ml_stability"] >= _ML_MIN_STABILITY, (
        f"{algo}/{label}: MLTCP interleave stability "
        f"{out['ml_stability']:.3f} < {_ML_MIN_STABILITY}")
    # the baseline never shakes off its synchronized episodes: per run it
    # fails to re-converge from at least one fault window, and its
    # stability stays strictly below MLTCP's on the identical gauntlet
    assert not any(r.telemetry.all_events_reconverged for r in base), \
        f"{algo}/{label}: unmodified baseline re-converged after faults"
    margin = _BASE_STABILITY_MARGIN[algo]
    assert (out["baseline_stability"]
            <= out["ml_stability"] - margin), (
        f"{algo}/{label}: baseline interleave stability "
        f"{out['baseline_stability']:.3f} not below MLTCP's "
        f"{out['ml_stability']:.3f} by {margin}")
    return out


def run(algos=("reno", "cubic", "dcqcn")) -> tuple[dict, int]:
    pr = common.run_plan(make_plan(algos))
    out: dict = {}
    for algo in algos:
        for label in SCHEDULES:
            out[f"{algo}/{label}"] = _summarize(
                algo, label,
                pr.select(algo=algo, variant="OFF", schedule=label),
                pr.select(algo=algo, variant="WI", schedule=label))
    worst = max(v for s in out.values()
                for v in s["worst_reinterleave_iters"].values()
                if v is not None)
    out["_worst_reinterleave_iters"] = worst
    return out, pr.n_ticks


if __name__ == "__main__":
    import json
    res, _ = run()
    print(json.dumps(res, indent=1))
