"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = wall time per
simulator tick across the benchmark's simulations) and *merges* the full
derived metrics into results/benchmarks.json keyed by suite name (existing
suites' entries from earlier runs survive — the perf trajectory is append/
update, never overwrite-all).

Each suite returns ``(derived_metrics, n_ticks)`` where n_ticks is summed
from the actual configs it ran (`PlanResult.n_ticks`) — not a hand-kept
constant — so the µs/tick column stays honest as suites grow axes or
change sim times.

Quick mode (default) scales workloads per benchmarks/common.py; set
REPRO_FULL=1 for paper-scale runs.
"""
from __future__ import annotations

from benchmarks import (
    churn,
    circular,
    common,
    convergence,
    diversity,
    kernel_sweep,
    parameters,
    partial_compat,
    speedup_vs_jobs,
    stragglers,
    timeline,
)


def main() -> None:
    suites = [
        ("fig5_timeline", timeline.run),
        ("fig7_9_convergence", convergence.run),
        ("fig10_speedup_vs_jobs", speedup_vs_jobs.run),
        ("fig11_table2_diversity", diversity.run),
        ("fig12_stragglers", stragglers.run),
        ("fig13_partial_compat", partial_compat.run),
        ("fig14_circular_dependency", circular.run),
        ("fig15_agg_functions", parameters.fig15_agg_functions),
        ("fig16_slope_intercept", parameters.fig16_heatmap),
        ("fig17_wi_vs_md", parameters.fig17_wi_vs_md),
        ("kernel_sweep", kernel_sweep.run),
        ("churn_gauntlet", churn.run),
    ]
    done = 0
    for name, fn in suites:
        r = common.timed(name, fn)
        # merge as each suite finishes: a crash in a later suite must not
        # discard the hours the earlier ones already spent; _health records
        # the suite's fusion/cache counters (kernel fallbacks, cache hits,
        # compile groups) so the perf trajectory tracks them per run
        common.merge_results({name: {**r.derived, "_health": r.health}})
        done += 1
        print(r.csv_line(), flush=True)
    print(f"# merged {done} suites into {common.RESULTS_PATH}")


if __name__ == "__main__":
    main()
