"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = wall time per
simulator tick across the benchmark's simulations) and writes the full
derived metrics to results/benchmarks.json.

Each suite returns ``(derived_metrics, n_ticks)`` where n_ticks is summed
from the actual configs it ran (`PlanResult.n_ticks`) — not a hand-kept
constant — so the µs/tick column stays honest as suites grow axes or
change sim times.

Quick mode (default) scales workloads per benchmarks/common.py; set
REPRO_FULL=1 for paper-scale runs.
"""
from __future__ import annotations

import json
import os

from benchmarks import (
    circular,
    common,
    convergence,
    diversity,
    parameters,
    partial_compat,
    speedup_vs_jobs,
    stragglers,
)


def main() -> None:
    suites = [
        ("fig7_9_convergence", convergence.run),
        ("fig10_speedup_vs_jobs", speedup_vs_jobs.run),
        ("fig11_table2_diversity", diversity.run),
        ("fig12_stragglers", stragglers.run),
        ("fig13_partial_compat", partial_compat.run),
        ("fig14_circular_dependency", circular.run),
        ("fig15_agg_functions", parameters.fig15_agg_functions),
        ("fig16_slope_intercept", parameters.fig16_heatmap),
        ("fig17_wi_vs_md", parameters.fig17_wi_vs_md),
    ]
    all_results = {}
    lines = []
    for name, fn in suites:
        r = common.timed(name, fn)
        all_results[name] = r.derived
        lines.append(r.csv_line())
        print(r.csv_line(), flush=True)
    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump(all_results, f, indent=1)
    print("# wrote results/benchmarks.json")


if __name__ == "__main__":
    main()
