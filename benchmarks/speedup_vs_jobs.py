"""Figure 10 — training-iteration speedup as competing-job count grows.

The paper: MLTCP-Reno plateaus ~1.3x avg / 1.5x p99; MLQCN reaches 2x / 4x
as DCQCN's congestion collapse (pause storms) worsens with more jobs.
"""
from __future__ import annotations

from benchmarks import common
from repro import netsim


def run(algos=("reno", "dcqcn"), job_counts=(2, 3, 4, 5, 6)) -> tuple[dict, int]:
    out = {}
    total_sims = 0
    for algo in algos:
        for n in job_counts:
            topo = netsim.dumbbell(n, sockets_per_job=2)
            profs = common.gpt2(n)
            base = common.sim(topo, profs, common.protocol(algo, "OFF"))
            ml = common.sim(topo, profs, common.protocol(algo, "WI"))
            sp = netsim.speedup_stats(base, ml)
            out[f"{algo}_{n}jobs"] = {
                "avg_speedup": round(sp["avg_speedup"], 3),
                "p99_speedup": round(sp["p99_speedup"], 3),
            }
            total_sims += 2
    return out, int(common.SIM_TIME / common.DT) * total_sims


if __name__ == "__main__":
    import json
    print(json.dumps(run()[0], indent=1))
