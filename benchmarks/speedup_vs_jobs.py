"""Figure 10 — training-iteration speedup as competing-job count grows.

The paper: MLTCP-Reno plateaus ~1.3x avg / 1.5x p99; MLQCN reaches 2x / 4x
as DCQCN's congestion collapse (pause storms) worsens with more jobs.

One plan per algorithm: variant x job-count x seed.  The job-count axis is
*padded* — every count runs on the largest dumbbell with trailing jobs
masked off (`SweepParams.job_active`) — so the whole grid compiles exactly
twice (once per variant) instead of once per (variant, count) cell, and the
reported numbers carry multi-seed error bars.
"""
from __future__ import annotations

from benchmarks import common
from repro import netsim


def _plan(algo: str, job_counts) -> netsim.Plan:
    def build(pt):
        n = pt["n_jobs"]
        return common.build_cfg(netsim.dumbbell(n, sockets_per_job=2),
                                common.gpt2(n),
                                common.protocol(algo, pt["variant"]))
    return common.plan(build, name=f"fig10-{algo}",
                       variant=("OFF", "WI"),
                       n_jobs=tuple(job_counts),
                       seed=common.seed_axis())


def run(algos=("reno", "dcqcn"), job_counts=(2, 3, 4, 5, 6)) -> tuple[dict, int]:
    out = {}
    n_ticks = 0
    for algo in algos:
        pr = common.run_plan(_plan(algo, job_counts))
        assert pr.n_compile_groups == 2, pr.n_compile_groups
        for n in job_counts:
            sp = netsim.sweep_speedup_stats(
                pr.select(variant="OFF", n_jobs=n),
                pr.select(variant="WI", n_jobs=n))
            out[f"{algo}_{n}jobs"] = {
                "avg_speedup": round(sp["avg_speedup"], 3),
                "p99_speedup": round(sp["p99_speedup"], 3),
                "avg_speedup_std": round(sp["avg_speedup_std"], 3),
            }
        n_ticks += pr.n_ticks
    return out, n_ticks


if __name__ == "__main__":
    import json
    print(json.dumps(run()[0], indent=1))
