"""Figure 10 — training-iteration speedup as competing-job count grows.

The paper: MLTCP-Reno plateaus ~1.3x avg / 1.5x p99; MLQCN reaches 2x / 4x
as DCQCN's congestion collapse (pause storms) worsens with more jobs.

Each (algo, n_jobs) cell changes the topology (static), so it compiles its
own program — but baseline and MLTCP both run their whole multi-seed grid
as one batched `simulate_sweep`, and the reported numbers carry error bars.
"""
from __future__ import annotations

from benchmarks import common
from repro import netsim


def run(algos=("reno", "dcqcn"), job_counts=(2, 3, 4, 5, 6)) -> tuple[dict, int]:
    out = {}
    total_sims = 0
    for algo in algos:
        for n in job_counts:
            topo = netsim.dumbbell(n, sockets_per_job=2)
            profs = common.gpt2(n)
            base = common.sim_seeds(topo, profs, common.protocol(algo, "OFF"))
            ml = common.sim_seeds(topo, profs, common.protocol(algo, "WI"))
            sp = netsim.sweep_speedup_stats(base, ml)
            out[f"{algo}_{n}jobs"] = {
                "avg_speedup": round(sp["avg_speedup"], 3),
                "p99_speedup": round(sp["p99_speedup"], 3),
                "avg_speedup_std": round(sp["avg_speedup_std"], 3),
            }
            total_sims += 2 * len(common.SEEDS)
    return out, int(common.SIM_TIME / common.DT) * total_sims


if __name__ == "__main__":
    import json
    print(json.dumps(run()[0], indent=1))
