"""Fused CC-tick kernel vs jnp oracle on a plan-shaped K>1 sweep (µs/tick).

The CC tick is the simulator's hot loop (MLTCP §4: per-iteration byte-scaled
window updates across all flows, every tick).  Since the protocol scalars
became kernel *operands* (DESIGN.md §4) the fused Pallas kernel stays
engaged under real `run_plan` sweeps, so this suite times exactly that
shape: a job-count x seed plan run twice — once through the jnp oracle,
once with ``use_pallas_kernel=True`` — and reports µs/tick for both plus
the ratio.  Each mode is compiled by a warm-up run first, so the numbers
are steady-state execution, not trace+compile.

Interpretation note: under ``REPRO_INTERPRET=1`` (the CPU-container
default) the kernel body runs through the Pallas *interpreter*, which
emulates the TPU grid and is expected to be slower than the oracle — the
suite is then a regression harness for the dispatch overhead and a
correctness gate (``n_kernel_fallbacks == 0``).  On real TPUs
(``REPRO_INTERPRET=0``) the same entry point measures the genuine fused
speedup.  Results merge into results/benchmarks.json under
``kernel_sweep`` (existing suites' entries survive — see
`common.merge_results`).
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks import common
from repro import netsim
from repro.kernels import ops as kernel_ops


def _plan(use_kernel: bool, job_counts) -> netsim.Plan:
    def build(pt):
        n = pt["n_jobs"]
        cfg = common.build_cfg(netsim.dumbbell(n, sockets_per_job=2),
                               common.gpt2(n),
                               common.protocol("reno", "WI"))
        return dataclasses.replace(cfg, use_pallas_kernel=use_kernel)
    return common.plan(build, name=f"kernel-sweep-{use_kernel}",
                       n_jobs=tuple(job_counts),
                       seed=common.seed_axis())


def make_plan(use_kernel: bool = True, job_counts=(2, 3)) -> netsim.Plan:
    """The kernel-mode plan (default: fused).  `repro.analysis --plan
    kernel_sweep` lints this lowering to prove the pallas_call is present —
    the static form of the suite's `n_kernel_fallbacks == 0` assert."""
    return _plan(use_kernel, job_counts)


def _timed_plan(use_kernel: bool, job_counts) -> tuple[float, int, int]:
    """(steady-state seconds, total ticks, kernel fallbacks) for one mode.

    Fallbacks are read off the *warm-up* run: FALLBACK_COUNT increments at
    trace time, and the timed run hits the jit cache (trace count 0), so
    its delta is always zero — only the run that traces can tell whether
    the kernel actually engaged.
    """
    plan = _plan(use_kernel, job_counts)
    warmup = common.run_plan(plan)              # warm-up: trace + compile
    t0 = time.time()
    pr = common.run_plan(plan)                  # same jit cache entries
    wall = time.time() - t0
    return wall, pr.n_ticks, warmup.n_kernel_fallbacks


def run(job_counts=(2, 3)) -> tuple[dict, int]:
    oracle_s, n_ticks, _ = _timed_plan(False, job_counts)
    fused_s, fused_ticks, fallbacks = _timed_plan(True, job_counts)
    assert fallbacks == 0, (
        f"use_pallas_kernel=True fell back to the jnp oracle {fallbacks} "
        f"times — the sweep did not run fused")
    oracle_us = 1e6 * oracle_s / max(n_ticks, 1)
    fused_us = 1e6 * fused_s / max(fused_ticks, 1)
    out = {
        "oracle_us_per_tick": round(oracle_us, 3),
        "fused_us_per_tick": round(fused_us, 3),
        "fused_over_oracle": round(fused_us / max(oracle_us, 1e-9), 3),
        "kernel_fallbacks": fallbacks,
        "interpret": kernel_ops.INTERPRET,
    }
    # each mode executed its plan twice (warm-up + timed) — report all the
    # ticks actually simulated so the harness's us/tick CSV stays honest
    return out, 2 * (n_ticks + fused_ticks)


if __name__ == "__main__":
    import json
    derived, _ = run()
    common.merge_results({"kernel_sweep": derived})
    print(json.dumps(derived, indent=1))
