"""Shared helpers for the paper-figure benchmarks.

Every suite declares a `netsim.Plan` — named axes (scheme, F family, job
count, seed, ...) over a config builder — and `netsim.run_plan` partitions
the matrix into compile groups, so job-count grids share one padded program
and every result carries its `SweepPoint` labels.  Suites report their
simulated tick counts from `PlanResult.n_ticks` / `SimResult.cfg`, so the
µs/tick CSV tracks the configs instead of hand-kept constants.

Workload scaling: testbed iterations are O(100 ms); to keep CPU wall-time
tractable the benchmarks run the same phase *ratios* scaled by
``WORK_SCALE`` (interleaving dynamics depend on ratios, not absolutes —
validated by tests/test_netsim.py::test_scale_invariance). Full-scale runs:
``REPRO_FULL=1 PYTHONPATH=src python -m benchmarks.run``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro import netsim, workload
from repro.core import Algo, CCParams, MLTCPConfig, Variant

FULL = bool(int(os.environ.get("REPRO_FULL", "0")))
SMOKE = bool(int(os.environ.get("REPRO_SMOKE", "0")))  # CI regression smoke
WORK_SCALE = 1.0 if FULL else 0.25
SIM_TIME = 20.0 if FULL else (1.5 if SMOKE else 4.0)
DT = 2e-5
# seed grid for error bars — a free vmap axis via netsim.simulate_sweep
SEEDS = (1, 2, 3) if FULL else ((1,) if SMOKE else (1, 2))

# paper §4.1 defaults per scheme
PARAMS = {
    ("reno", "WI"): (1.75, 0.25),
    ("reno", "MD"): (1.0, 1.0),
    ("cubic", "WI"): (1.0, 0.5),
    ("cubic", "MD"): (0.8, 0.8),
    ("dcqcn", "WI"): (1.067, 0.267),
    ("dcqcn", "MD"): (1.067, 0.267),
}
ALGOS = {"reno": Algo.RENO, "cubic": Algo.CUBIC, "dcqcn": Algo.DCQCN}

# ECN thresholds for the RoCE fabric; RED drop thresholds for TCP
RED_BY_ALGO = {
    "reno": dict(red_qmin=150e3, red_qmax=1.5e6, red_pmax=0.12),
    "cubic": dict(red_qmin=150e3, red_qmax=1.5e6, red_pmax=0.12),
    "dcqcn": dict(red_qmin=50e3, red_qmax=400e3, red_pmax=0.2),
}


def protocol(algo: str, variant: str = "WI", slope=None, intercept=None,
             f_spec: str = "linear", **cfg_kw) -> MLTCPConfig:
    var = {"OFF": Variant.OFF, "WI": Variant.WI, "MD": Variant.MD,
           "BOTH": Variant.BOTH}[variant]
    s_def, i_def = PARAMS.get((algo, "WI" if variant == "OFF" else variant),
                              (1.75, 0.25))
    return MLTCPConfig(
        cc=CCParams(algo=int(ALGOS[algo]), variant=int(var), tick_dt=DT,
                    rtt=100e-6),
        slope=s_def if slope is None else slope,
        intercept=i_def if intercept is None else intercept,
        f_spec=f_spec,
        **cfg_kw)


def build_cfg(topo, profiles, proto, *, sim_time=None, seed=1,
              straggle_prob=None, start_offset=None, cassini=None,
              static_job_factors=None, scale=None, **kw) -> netsim.SimConfig:
    scale = WORK_SCALE if scale is None else scale
    profiles = [p.scaled(scale) for p in profiles]
    jobs = workload.jobspec_from_profiles(profiles,
                                          straggle_prob=straggle_prob,
                                          start_offset=start_offset)
    algo = {int(v): k for k, v in ALGOS.items()}[proto.cc.algo]
    return netsim.SimConfig(
        topo=topo, jobs=jobs, protocol=proto,
        sim_time=SIM_TIME if sim_time is None else sim_time, dt=DT,
        seed=seed, cassini=cassini, static_job_factors=static_job_factors,
        **{**RED_BY_ALGO[algo], **kw})


def plan(build, *, name: str = "", where=None, **axes) -> netsim.Plan:
    """Declare an experiment plan from keyword axes.

    Each ``axes`` value is either a value sequence or a `netsim.Axis`
    (renamed to its keyword); ``build`` maps a point's label dict to its
    `SimConfig`.  Run with `run_plan`.
    """
    resolved = []
    for key, v in axes.items():
        if isinstance(v, netsim.Axis):
            resolved.append(dataclasses.replace(v, name=key))
        else:
            resolved.append(netsim.Axis(key, tuple(v)))
    return netsim.Plan(name=name, axes=tuple(resolved), build=build,
                       where=where)


# Per-suite fusion/cache health, accumulated across every plan a suite runs
# (suites may run several); `timed` resets it per benchmark and attaches the
# totals to the BenchResult so run.py can print + merge them.  The last
# three keys are the static analyzer's verdict: compile groups the plan
# lint predicted before the run, plans whose executed group count diverged
# from that prediction, and non-info plan-lint findings (avoidable splits).
_PLAN_HEALTH = {"n_kernel_fallbacks": 0, "n_cache_hits": 0,
                "n_compile_groups": 0, "n_groups_predicted": 0,
                "n_group_mispredicts": 0, "n_plan_findings": 0,
                "n_group_errors": 0, "n_budget_mismatches": 0}


def reset_plan_health() -> None:
    for k in _PLAN_HEALTH:
        _PLAN_HEALTH[k] = 0


def plan_health() -> dict:
    return dict(_PLAN_HEALTH)


def _budget_mismatches(pr: netsim.PlanResult) -> int:
    """Measured-vs-predicted cost cross-check: every profiled group whose
    envelope (`GroupProfile.cost_envelope`, only filled under
    ``profile=True``) matches *no* recorded budget of the same structural
    signature.  Groups without an envelope, without a same-signature
    baseline, or under a mismatched env are skipped, not counted."""
    measured = [g for g in pr.profile.groups
                if g.cost_envelope is not None and g.signature]
    if not measured:
        return 0
    from repro.analysis.hlo_budget import BudgetBook

    book = BudgetBook()
    return sum(1 for g in measured
               if book.matches_any(g.signature, g.cost_envelope) is False)


def run_plan(p: netsim.Plan, **kw) -> netsim.PlanResult:
    """Execute a plan (thin wrapper so suites share one entry point and
    their fusion/cache health aggregates per suite).

    Each execution is preceded by the plan lint: the predicted compile
    groups and any non-info findings land in the suite's health block, and
    an executed group count that diverges from the prediction is counted
    as a mispredict — the benchmarks continuously cross-validate the
    static analyzer against reality.
    """
    from repro.analysis import plan_lint

    findings, facts = plan_lint.lint_plan(
        p, label=p.name or "plan", pad_jobs=kw.get("pad_jobs", True),
        telemetry=kw.get("telemetry"))
    predicted = facts["groups"]

    pr = netsim.run_plan(p, **kw)
    _PLAN_HEALTH["n_budget_mismatches"] += _budget_mismatches(pr)
    _PLAN_HEALTH["n_kernel_fallbacks"] += pr.n_kernel_fallbacks
    _PLAN_HEALTH["n_cache_hits"] += pr.n_cache_hits
    _PLAN_HEALTH["n_compile_groups"] += pr.n_compile_groups
    # keep_going=True salvage: failed compile groups land here instead of
    # aborting the suite; a nonzero count in _health flags the partial run
    _PLAN_HEALTH["n_group_errors"] += len(pr.group_errors)
    _PLAN_HEALTH["n_groups_predicted"] += predicted
    _PLAN_HEALTH["n_group_mispredicts"] += int(
        predicted != pr.n_compile_groups)
    _PLAN_HEALTH["n_plan_findings"] += sum(
        1 for f in findings if f.effective_severity != "info")
    return pr


def seed_axis(seeds=None) -> netsim.Axis:
    """The shared multi-seed error-bar axis (a free `simulate_sweep` vmap
    lane; every suite appends it to its plan)."""
    return netsim.Axis("seed", tuple(SEEDS if seeds is None else seeds))


def sim(topo, profiles, proto, **kw) -> netsim.SimResult:
    """One simulation as a single-point plan (kept for one-off runs)."""
    pr = run_plan(plan(lambda pt: build_cfg(topo, profiles, proto, **kw),
                       name="single"))
    return pr.results[0]


RESULTS_PATH = os.path.join("results", "benchmarks.json")


def merge_results(new: dict, path: str = RESULTS_PATH) -> dict:
    """Merge suite results into the benchmarks JSON, keyed by suite name.

    Load-if-exists, update, dump — a partial run (one suite, a new suite)
    updates only its own keys instead of destroying the perf trajectory the
    other suites recorded on earlier runs.  The dump goes through a temp
    file + os.replace so a crash mid-write can never leave a truncated
    file that a later run would "recover" from as empty.  Returns the
    merged dict.
    """
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    data: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError):
            data = {}          # corrupt/unreadable: rewrite from this run
    data.update(new)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1)
    os.replace(tmp, path)
    return data


@dataclasses.dataclass
class BenchResult:
    name: str
    wall_s: float
    n_ticks: int
    derived: dict
    # fusion/cache health over every plan the suite ran (plan_health())
    health: dict = dataclasses.field(default_factory=dict)

    def csv_line(self) -> str:
        us = 1e6 * self.wall_s / max(self.n_ticks, 1)
        key, val = next(iter(self.derived.items()))
        line = f"{self.name},{us:.3f},{key}={val}"
        if self.health:
            line += (f",fallbacks={self.health.get('n_kernel_fallbacks', 0)}"
                     f",cache_hits={self.health.get('n_cache_hits', 0)}")
        return line


def timed(name: str, fn) -> BenchResult:
    reset_plan_health()
    t0 = time.time()
    derived, n_ticks = fn()
    return BenchResult(name, time.time() - t0, n_ticks, derived,
                       health=plan_health())


def gpt2(n: int = 1) -> list[workload.CommProfile]:
    return [workload.profile_for("gpt2") for _ in range(n)]
