"""Figure 13 — partially compatible jobs: MLQCN vs Static [67].

Sweep compatibility by varying the jobs' compute:comm ratios (the paper
varies batch size). Static = fixed unfair per-job factors; MLQCN adapts.
The paper: below compat ~0.7 Static's p99 drops under 1.0 (worse than
default DCQCN) while MLQCN stays >= 1.

One plan: compute-scale x scheme x seed.  The compute scale only changes
workload *values*, which are traced sweep leaves, so every scale shares a
trace; the Static baseline's per-job factors ride the same group via the
adaptive-sentinel encoding (factor < 0 keeps F).  The whole grid runs in
two compile groups — base (OFF) and {mlqcn, static} (WI) — with seed error
bars batched on the sweep axis.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import netsim, workload

STATIC_FACTORS = np.asarray([1.3, 1.0, 0.7])


def _profs_for(cs):
    base_prof = workload.profile_for("gpt2")
    return [base_prof.compute_scaled(cs) for _ in range(3)]


def make_plan(compute_scales=(1.5, 1.0, 0.7, 0.45, 0.25)) -> netsim.Plan:
    """The fig13 grid as a plan, buildable without running (lintable via
    `repro.analysis --plan fig13`)."""
    topo = netsim.dumbbell(3, sockets_per_job=2)

    def build(pt):
        # Static [67]: constant per-job factors replace F; needs a non-OFF
        # variant so the factors reach the increase hook
        variant = "OFF" if pt["scheme"] == "base" else "WI"
        return common.build_cfg(
            topo, _profs_for(pt["cs"]), common.protocol("dcqcn", variant),
            static_job_factors=(STATIC_FACTORS if pt["scheme"] == "static"
                                else None))

    return common.plan(
        build, name="fig13",
        cs=tuple(compute_scales), scheme=("base", "mlqcn", "static"),
        seed=common.seed_axis())


def run(compute_scales=(1.5, 1.0, 0.7, 0.45, 0.25)) -> tuple[dict, int]:
    pr = common.run_plan(make_plan(compute_scales))
    assert pr.n_compile_groups <= 2, pr.n_compile_groups
    assert pr.n_kernel_fallbacks == 0
    out = {}
    for cs in compute_scales:
        compat = workload.compatibility_score(
            _profs_for(cs)[0].scaled(common.WORK_SCALE),
            _profs_for(cs)[1].scaled(common.WORK_SCALE))
        base = pr.select(cs=cs, scheme="base")
        sp_ml = netsim.sweep_speedup_stats(base,
                                           pr.select(cs=cs, scheme="mlqcn"))
        sp_st = netsim.sweep_speedup_stats(base,
                                           pr.select(cs=cs, scheme="static"))
        out[f"compat={compat:.2f}"] = {
            "mlqcn_avg": round(sp_ml["avg_speedup"], 3),
            "mlqcn_p99": round(sp_ml["p99_speedup"], 3),
            "static_avg": round(sp_st["avg_speedup"], 3),
            "static_p99": round(sp_st["p99_speedup"], 3),
        }
    return out, pr.n_ticks


if __name__ == "__main__":
    import json
    print(json.dumps(run()[0], indent=1))
