"""Figure 13 — partially compatible jobs: MLQCN vs Static [67].

Sweep compatibility by varying the jobs' compute:comm ratios (the paper
varies batch size). Static = fixed unfair per-job factors; MLQCN adapts.
The paper: below compat ~0.7 Static's p99 drops under 1.0 (worse than
default DCQCN) while MLQCN stays >= 1.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks import common
from repro import netsim, workload


def _job_with_compute(base, compute_s: float):
    return dataclasses.replace(base, compute_s=(compute_s,))


def run(compute_scales=(1.5, 1.0, 0.7, 0.45, 0.25)) -> tuple[dict, int]:
    topo = netsim.dumbbell(3, sockets_per_job=2)
    base_prof = workload.profile_for("gpt2")
    out = {}
    n_sims = 0
    for cs in compute_scales:
        profs = [_job_with_compute(base_prof, base_prof.compute_s[0] * cs)
                 for _ in range(3)]
        compat = workload.compatibility_score(
            profs[0].scaled(common.WORK_SCALE),
            profs[1].scaled(common.WORK_SCALE))
        base = common.sim(topo, profs, common.protocol("dcqcn", "OFF"))
        ml = common.sim(topo, profs, common.protocol("dcqcn", "WI"))
        # Static [67]: constant per-job factors replace F; needs a non-OFF
        # variant so the factors reach the increase hook
        static = common.sim(topo, profs, common.protocol("dcqcn", "WI"),
                            static_job_factors=np.asarray([1.3, 1.0, 0.7]))
        sp_ml = netsim.speedup_stats(base, ml)
        sp_st = netsim.speedup_stats(base, static)
        out[f"compat={compat:.2f}"] = {
            "mlqcn_avg": round(sp_ml["avg_speedup"], 3),
            "mlqcn_p99": round(sp_ml["p99_speedup"], 3),
            "static_avg": round(sp_st["avg_speedup"], 3),
            "static_p99": round(sp_st["p99_speedup"], 3),
        }
        n_sims += 3
    return out, int(common.SIM_TIME / common.DT) * n_sims


if __name__ == "__main__":
    import json
    print(json.dumps(run()[0], indent=1))
