"""Figures 15, 16, 17 — parameter guidelines.

  fig15: aggressiveness functions F1..F4 (increasing) interleave and speed
         up; F5, F6 (decreasing) do not — the SRPT-reinforcement claim.
  fig16: S x I sweep heatmap of MLTCP-Reno speedups.
  fig17: WI vs MD variants perform similarly (Reno and CUBIC).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import netsim


def fig15_agg_functions(fns=("F1", "F2", "F3", "F4", "F5", "F6")
                        ) -> tuple[dict, int]:
    topo = netsim.dumbbell(3, sockets_per_job=2)
    profs = common.gpt2(3)
    base = common.sim(topo, profs, common.protocol("reno", "OFF"))
    out = {}
    for f in fns:
        res = common.sim(topo, profs, common.protocol("reno", "WI",
                                                      f_spec=f))
        sp = netsim.speedup_stats(base, res)
        out[f] = {
            "avg_speedup": round(sp["avg_speedup"], 3),
            "interleave": round(netsim.mean_pairwise_interleave(res), 3),
        }
    return out, int(common.SIM_TIME / common.DT) * (len(fns) + 1)


def fig16_heatmap(slopes=(0.5, 1.0, 1.75, 2.5),
                  intercepts=(0.1, 0.25, 0.5, 1.0)) -> tuple[dict, int]:
    topo = netsim.dumbbell(2, sockets_per_job=2)
    profs = common.gpt2(2)
    base = common.sim(topo, profs, common.protocol("reno", "OFF"))
    grid = {}
    n = 1
    for s in slopes:
        for i in intercepts:
            res = common.sim(topo, profs,
                             common.protocol("reno", "WI", slope=s,
                                             intercept=i))
            sp = netsim.speedup_stats(base, res)
            grid[f"S={s},I={i}"] = {
                "avg_speedup": round(sp["avg_speedup"], 3),
                "p99_speedup": round(sp["p99_speedup"], 3),
            }
            n += 1
    best = max(grid, key=lambda k: grid[k]["avg_speedup"])
    grid["best"] = {"at": best, **grid[best]}
    return grid, int(common.SIM_TIME / common.DT) * n


def fig17_wi_vs_md() -> tuple[dict, int]:
    topo = netsim.dumbbell(2, sockets_per_job=2)
    profs = common.gpt2(2)
    out = {}
    n = 0
    for algo in ("reno", "cubic"):
        base = common.sim(topo, profs, common.protocol(algo, "OFF"))
        for variant in ("WI", "MD"):
            res = common.sim(topo, profs, common.protocol(algo, variant))
            sp = netsim.speedup_stats(base, res)
            out[f"{algo}-{variant}"] = {
                "avg_speedup": round(sp["avg_speedup"], 3),
                "p99_speedup": round(sp["p99_speedup"], 3),
            }
            n += 1
        n += 1
    return out, int(common.SIM_TIME / common.DT) * n


if __name__ == "__main__":
    import json
    print(json.dumps({"fig15": fig15_agg_functions()[0],
                      "fig16": fig16_heatmap()[0],
                      "fig17": fig17_wi_vs_md()[0]}, indent=1))
