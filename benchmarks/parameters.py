"""Figures 15, 16, 17 — parameter guidelines.

  fig15: aggressiveness functions F1..F4 (increasing) interleave and speed
         up; F5, F6 (decreasing) do not — the SRPT-reinforcement claim.
  fig16: S x I sweep heatmap of MLTCP-Reno speedups — the whole grid runs
         as ONE `netsim.simulate_sweep` call (one trace, one compile).
  fig17: WI vs MD variants perform similarly (Reno and CUBIC).

fig15/fig17 vary *static* protocol structure (F family, variant) so each
scheme compiles once, but every scheme runs a batched multi-seed sweep for
error bars; fig16 varies only traced scalars, so the full heatmap shares a
single compiled program with the seed axis folded into the same batch.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import netsim


def fig15_agg_functions(fns=("F1", "F2", "F3", "F4", "F5", "F6")
                        ) -> tuple[dict, int]:
    topo = netsim.dumbbell(3, sockets_per_job=2)
    profs = common.gpt2(3)
    base = common.sim_seeds(topo, profs, common.protocol("reno", "OFF"))
    out = {}
    for f in fns:
        res = common.sim_seeds(topo, profs,
                               common.protocol("reno", "WI", f_spec=f))
        sp = netsim.sweep_speedup_stats(base, res)
        inter = [netsim.mean_pairwise_interleave(r) for r in res]
        out[f] = {
            "avg_speedup": round(sp["avg_speedup"], 3),
            "avg_speedup_std": round(sp["avg_speedup_std"], 3),
            "interleave": round(float(np.mean(inter)), 3),
        }
    n_sims = len(common.SEEDS) * (len(fns) + 1)
    return out, int(common.SIM_TIME / common.DT) * n_sims


def fig16_heatmap(slopes=(0.5, 1.0, 1.75, 2.5),
                  intercepts=(0.1, 0.25, 0.5, 1.0)) -> tuple[dict, int]:
    topo = netsim.dumbbell(2, sockets_per_job=2)
    profs = common.gpt2(2)
    seeds = list(common.SEEDS)
    base = common.sim_seeds(topo, profs, common.protocol("reno", "OFF"))
    # one batched program: K = |S| * |I| * |seeds| grid points
    results, points = common.sim_grid(
        topo, profs, common.protocol("reno", "WI"),
        {"slope": slopes, "intercept": intercepts, "seed": seeds})
    grid = {}
    for (s, i) in [(s, i) for s in slopes for i in intercepts]:
        idx = [k for k, p in enumerate(points)
               if p["slope"] == s and p["intercept"] == i]
        # pair each seed's MLTCP run with the same seed's baseline
        sp = netsim.sweep_speedup_stats(base, [results[k] for k in idx])
        grid[f"S={s},I={i}"] = {
            "avg_speedup": round(sp["avg_speedup"], 3),
            "p99_speedup": round(sp["p99_speedup"], 3),
            "avg_speedup_std": round(sp["avg_speedup_std"], 3),
        }
    best = max(grid, key=lambda k: grid[k]["avg_speedup"])
    grid["best"] = {"at": best, **grid[best]}
    n_sims = len(points) + len(seeds)
    return grid, int(common.SIM_TIME / common.DT) * n_sims


def fig17_wi_vs_md() -> tuple[dict, int]:
    topo = netsim.dumbbell(2, sockets_per_job=2)
    profs = common.gpt2(2)
    out = {}
    n = 0
    for algo in ("reno", "cubic"):
        base = common.sim_seeds(topo, profs, common.protocol(algo, "OFF"))
        for variant in ("WI", "MD"):
            res = common.sim_seeds(topo, profs,
                                   common.protocol(algo, variant))
            sp = netsim.sweep_speedup_stats(base, res)
            out[f"{algo}-{variant}"] = {
                "avg_speedup": round(sp["avg_speedup"], 3),
                "p99_speedup": round(sp["p99_speedup"], 3),
                "avg_speedup_std": round(sp["avg_speedup_std"], 3),
            }
            n += len(common.SEEDS)
        n += len(common.SEEDS)
    return out, int(common.SIM_TIME / common.DT) * n


if __name__ == "__main__":
    import json
    print(json.dumps({"fig15": fig15_agg_functions()[0],
                      "fig16": fig16_heatmap()[0],
                      "fig17": fig17_wi_vs_md()[0]}, indent=1))
