"""Figures 15, 16, 17 — parameter guidelines.

  fig15: aggressiveness functions F1..F4 (increasing) interleave and speed
         up; F5, F6 (decreasing) do not — the SRPT-reinforcement claim.
  fig16: S x I sweep heatmap of MLTCP-Reno speedups — slope/intercept are
         dynamic axes, so the whole heatmap (plus its baseline) is ONE plan
         with exactly two compile groups (OFF, WI).
  fig17: WI vs MD variants perform similarly (Reno and CUBIC).

Each suite is one plan; static axes (F family, variant, algorithm) become
compile groups, dynamic axes (slope, intercept, seed — and, since the
workload became traced leaves, phase programs and straggle probabilities)
ride the batched sweep inside each group, and selections by axis label
pair the seeds for the error bars.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import netsim


def fig15_agg_functions(fns=("F1", "F2", "F3", "F4", "F5", "F6")
                        ) -> tuple[dict, int]:
    topo = netsim.dumbbell(3, sockets_per_job=2)
    profs = common.gpt2(3)
    schemes = ("OFF",) + tuple(fns)

    def build(pt):
        s = pt["scheme"]
        proto = (common.protocol("reno", "OFF") if s == "OFF"
                 else common.protocol("reno", "WI", f_spec=s))
        return common.build_cfg(topo, profs, proto)

    pr = common.run_plan(common.plan(build, name="fig15",
                                     scheme=schemes, seed=common.seed_axis()))
    base = pr.select(scheme="OFF")
    out = {}
    for f in fns:
        res = pr.select(scheme=f)
        sp = netsim.sweep_speedup_stats(base, res)
        inter = [netsim.mean_pairwise_interleave(r) for r in res]
        out[f] = {
            "avg_speedup": round(sp["avg_speedup"], 3),
            "avg_speedup_std": round(sp["avg_speedup_std"], 3),
            "interleave": round(float(np.mean(inter)), 3),
        }
    return out, pr.n_ticks


def fig16_heatmap(slopes=(0.5, 1.0, 1.75, 2.5),
                  intercepts=(0.1, 0.25, 0.5, 1.0)) -> tuple[dict, int]:
    topo = netsim.dumbbell(2, sockets_per_job=2)
    profs = common.gpt2(2)

    # The baseline ignores S/I, so `where` prunes it to a single (S, I)
    # cell; the WI group's full S x I x seed grid is one compiled program.
    pr = common.run_plan(common.plan(
        lambda pt: common.build_cfg(topo, profs,
                                    common.protocol("reno", pt["variant"])),
        name="fig16",
        where=lambda pt: pt["variant"] == "WI" or (
            pt["slope"] == slopes[0] and pt["intercept"] == intercepts[0]),
        variant=("OFF", "WI"), slope=tuple(slopes),
        intercept=tuple(intercepts), seed=common.seed_axis()))
    assert pr.n_compile_groups == 2, pr.n_compile_groups
    assert pr.n_kernel_fallbacks == 0

    base = pr.select(variant="OFF")
    grid = {}
    for s in slopes:
        for i in intercepts:
            # seed-paired: selections share the (fastest) seed axis order
            sp = netsim.sweep_speedup_stats(
                base, pr.select(variant="WI", slope=s, intercept=i))
            grid[f"S={s},I={i}"] = {
                "avg_speedup": round(sp["avg_speedup"], 3),
                "p99_speedup": round(sp["p99_speedup"], 3),
                "avg_speedup_std": round(sp["avg_speedup_std"], 3),
            }
    best = max(grid, key=lambda k: grid[k]["avg_speedup"])
    grid["best"] = {"at": best, **grid[best]}
    return grid, pr.n_ticks


def fig17_wi_vs_md() -> tuple[dict, int]:
    topo = netsim.dumbbell(2, sockets_per_job=2)
    profs = common.gpt2(2)
    pr = common.run_plan(common.plan(
        lambda pt: common.build_cfg(topo, profs,
                                    common.protocol(pt["algo"], pt["variant"])),
        name="fig17",
        algo=("reno", "cubic"), variant=("OFF", "WI", "MD"),
        seed=common.seed_axis()))
    out = {}
    for algo in ("reno", "cubic"):
        base = pr.select(algo=algo, variant="OFF")
        for variant in ("WI", "MD"):
            sp = netsim.sweep_speedup_stats(
                base, pr.select(algo=algo, variant=variant))
            out[f"{algo}-{variant}"] = {
                "avg_speedup": round(sp["avg_speedup"], 3),
                "p99_speedup": round(sp["p99_speedup"], 3),
                "avg_speedup_std": round(sp["avg_speedup_std"], 3),
            }
    return out, pr.n_ticks


if __name__ == "__main__":
    import json
    print(json.dumps({"fig15": fig15_agg_functions()[0],
                      "fig16": fig16_heatmap()[0],
                      "fig17": fig17_wi_vs_md()[0]}, indent=1))
