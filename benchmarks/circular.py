"""Figure 14 — circular dependency across jobs and links (Figure 2 topology).

Three GPT-2 jobs on the triangle: each competes with a different job on each
of its two links; the affinity graph has a loop, so Cassini has no feasible
schedule and Static has no consistent unfairness assignment. MLQCN converges
anyway (the favoritism signal is per-flow local).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import netsim, workload


def run() -> tuple[dict, int]:
    topo = netsim.triangle(sockets_per_job=2)
    profs = common.gpt2(3)
    base = common.sim(topo, profs, common.protocol("dcqcn", "OFF"))
    ml = common.sim(topo, profs, common.protocol("dcqcn", "WI"))
    sched, feasible = workload.cassini_schedule(
        topo, [p.scaled(common.WORK_SCALE) for p in profs])
    cas = common.sim(topo, profs, common.protocol("dcqcn", "OFF"),
                     cassini=sched)
    sp = netsim.speedup_stats(base, ml)
    sp_cas = netsim.speedup_stats(base, cas)
    out = {
        "cassini_has_schedule": feasible,       # False: loop detected
        "base_interleave": round(netsim.mean_pairwise_interleave(base), 3),
        "mlqcn_interleave": round(netsim.mean_pairwise_interleave(ml), 3),
        "mlqcn_avg_speedup": round(sp["avg_speedup"], 3),
        "mlqcn_p99_speedup": round(sp["p99_speedup"], 3),
        "cassini_avg_speedup": round(sp_cas["avg_speedup"], 3),
        "mean_link_util_mlqcn": round(float(np.mean(ml.trace_util)), 3),
    }
    return out, int(common.SIM_TIME / common.DT) * 3


if __name__ == "__main__":
    import json
    print(json.dumps(run()[0], indent=1))
