"""Figure 14 — circular dependency across jobs and links (Figure 2 topology).

Three GPT-2 jobs on the triangle: each competes with a different job on each
of its two links; the affinity graph has a loop, so Cassini has no feasible
schedule and Static has no consistent unfairness assignment. MLQCN converges
anyway (the favoritism signal is per-flow local).

One plan: scheme x seed (seed-averaged with error bars).  The Cassini
schedule rides the traced `cassini_*` sweep leaves (period <= 0 = off),
so base and cassini share the OFF-variant compile group.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import netsim, workload


def run() -> tuple[dict, int]:
    topo = netsim.triangle(sockets_per_job=2)
    profs = common.gpt2(3)
    sched, feasible = workload.cassini_schedule(
        topo, [p.scaled(common.WORK_SCALE) for p in profs])

    def build(pt):
        variant = "WI" if pt["scheme"] == "mlqcn" else "OFF"
        return common.build_cfg(
            topo, profs, common.protocol("dcqcn", variant),
            cassini=sched if pt["scheme"] == "cassini" else None)

    pr = common.run_plan(common.plan(
        build, name="fig14",
        scheme=("base", "mlqcn", "cassini"), seed=common.seed_axis()))
    base = pr.select(scheme="base")
    ml = pr.select(scheme="mlqcn")
    sp = netsim.sweep_speedup_stats(base, ml)
    sp_cas = netsim.sweep_speedup_stats(base, pr.select(scheme="cassini"))
    out = {
        "cassini_has_schedule": feasible,       # False: loop detected
        "base_interleave": round(float(np.mean(
            [netsim.mean_pairwise_interleave(r) for r in base])), 3),
        "mlqcn_interleave": round(float(np.mean(
            [netsim.mean_pairwise_interleave(r) for r in ml])), 3),
        "mlqcn_avg_speedup": round(sp["avg_speedup"], 3),
        "mlqcn_avg_speedup_std": round(sp["avg_speedup_std"], 3),
        "mlqcn_p99_speedup": round(sp["p99_speedup"], 3),
        "cassini_avg_speedup": round(sp_cas["avg_speedup"], 3),
        "mean_link_util_mlqcn": round(float(np.mean(
            [np.mean(r.trace_util) for r in ml])), 3),
    }
    return out, pr.n_ticks


if __name__ == "__main__":
    import json
    print(json.dumps(run()[0], indent=1))
